"""Pallas TPU kernel: blockwise causal/windowed flash attention.

Grid (BH, num_q_blocks, num_kv_blocks), kv innermost (sequential on TPU);
online-softmax running state (m, l, acc) lives in VMEM scratch across the
kv sweep; fully-masked kv blocks (future blocks under causality, blocks
left of the sliding window) are skipped with ``pl.when`` so they cost
neither MXU time nor VPU time.  Block shapes are multiples of (8, 128)
MXU/VREG tiling when S and D are (pad upstream otherwise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_vmem, l_vmem, acc_vmem,
            *, scale, causal, window, bq, bk, nk):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_vmem[...] = jnp.full_like(m_vmem, NEG_INF)
        l_vmem[...] = jnp.zeros_like(l_vmem)
        acc_vmem[...] = jnp.zeros_like(acc_vmem)

    needed = jnp.asarray(True)
    if causal:
        needed &= kj * bk <= qi * bq + (bq - 1)
    if window is not None:
        needed &= (kj + 1) * bk - 1 > qi * bq - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qp = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qp >= kp
        if window is not None:
            mask &= qp - kp < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_vmem[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_vmem[...] = l_vmem[...] * corr + p.sum(axis=1, keepdims=True)
        acc_vmem[...] = acc_vmem[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_vmem[...] = m_new

    @pl.when(kj == nk - 1)
    def _flush():
        o_ref[0] = (acc_vmem[...] /
                    jnp.maximum(l_vmem[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           block_q=512, block_k=512, interpret=True):
    """q,k,v: (BH, S, D) with kv pre-expanded to H heads. Returns (BH,S,D)."""
    BH, S, D = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    bq, bk = min(block_q, S), min(block_k, Skv)
    assert S % bq == 0 and Skv % bk == 0, (S, bq, Skv, bk)
    nq, nk = S // bq, Skv // bk
    kern = functools.partial(_kernel, scale=float(scale), causal=causal,
                             window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
