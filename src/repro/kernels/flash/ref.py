"""Oracle for the flash attention kernel: plain masked softmax attention.

Layout (BH, S, D): batch*heads flattened, kv already expanded to H heads.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def mha_ref(q, k, v, *, causal=True, window=None, scale=None):
    BH, S, D = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    s = jnp.einsum("bsd,bxd->bsx", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bsx,bxd->bsd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
