from .flash import flash_attention_pallas
from .ops import flash_attention
from .ref import mha_ref
