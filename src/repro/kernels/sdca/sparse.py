"""Pallas TPU kernel: local SDCA epoch on a padded-ELL sparse block.

Sparse sibling of ``sdca.sdca_epoch_pallas`` for news20-scale blocks.
Same TPU scheme -- sequential step grid, scalar-prefetched coordinate
order driving the row DMA, the primal block and dual deltas resident in
VMEM -- but the gathered row is the (1, k) ELL row (column ids + values)
instead of the (1, m_q) dense row, so the per-step DMA traffic scales
with the row's nonzero count, not the block width.

Inside the step the sparse row is combined with the dense VMEM-resident
``w`` by gather (``z_loc = sum(vals * w[cols])``) and scatter-ADD
(``w[cols] += d * vals``).  ELL padding slots carry (col=0, val=0): the
gather reads w[0] harmlessly and the scatter adds zero, so duplicate
index-0 slots are inert by construction.  The gather/scatter pair is
exact in interpret mode (CPU CI); on real TPUs it requires the dynamic
gather/scatter lowering of recent Mosaic -- real-TPU validation rides
the same ROADMAP follow-up as the dense kernels.

Supported losses: hinge (closed form), squared.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sdca import _static_scalar


def _kernel(idx_ref,            # scalar prefetch: (steps,) int32
            params_ref,         # scalar prefetch: (3,) f32 [beta, lam, n]
            cols_row_ref,       # (1, k) gathered ELL column ids
            vals_row_ref,       # (1, k) gathered ELL values
            y_row_ref,          # (1, 1) label
            mask_row_ref,       # (1, 1)
            alpha_row_ref,      # (1, 1) alpha0[i]
            w0_ref,             # (1, m_q) initial w block
            dalpha_ref,         # out: (n_p, 1)
            w_out_ref,          # out: (1, m_q)
            w_vmem,             # scratch: (1, m_q) f32
            dal_vmem,           # scratch: (n_p, 1) f32
            *, lam, n, Q, steps, loss, use_beta, runtime):
    h = pl.program_id(0)

    @pl.when(h == 0)
    def _init():
        w_vmem[...] = w0_ref[...].astype(jnp.float32)
        dal_vmem[...] = jnp.zeros_like(dal_vmem)

    i = idx_ref[h]
    ci = cols_row_ref[0, :]
    vi = vals_row_ref[0, :].astype(jnp.float32)
    yi = y_row_ref[0, 0].astype(jnp.float32)
    mi = mask_row_ref[0, 0].astype(jnp.float32)
    a_i = alpha_row_ref[0, 0].astype(jnp.float32) + dal_vmem[i, 0]
    # runtime mode (fleet): traced lam / n from the prefetch params;
    # static mode bakes the Python constants (kernel unchanged)
    lam_v = params_ref[1] if runtime else lam
    n_v = params_ref[2] if runtime else n

    w = w_vmem[0, :]
    zloc = jnp.sum(vi * jnp.take(w, ci, axis=0))
    x_sq = jnp.sum(vi * vi)
    denom = params_ref[0] if use_beta else x_sq
    denom = jnp.maximum(denom, 1e-12)

    if loss == "hinge":
        d = (yi / Q - zloc) * lam_v * n_v / denom
        lo = jnp.where(yi > 0, 0.0, -1.0)
        hi = jnp.where(yi > 0, 1.0, 0.0)
        d = jnp.clip(a_i + d, lo, hi) - a_i
    elif loss == "squared":
        num = yi / Q - a_i / (2.0 * Q) - zloc
        den = 1.0 / (2.0 * Q) + denom / (lam_v * n_v)
        d = num / jnp.maximum(den, 1e-12)
    else:
        raise ValueError(loss)
    d = d * mi

    w_vmem[0, :] = w.at[ci].add((d / (lam_v * n_v)) * vi)
    dal_vmem[i, 0] = dal_vmem[i, 0] + d

    @pl.when(h == steps - 1)
    def _flush():
        dalpha_ref[...] = dal_vmem[...]
        w_out_ref[...] = w_vmem[...]


def sdca_epoch_sparse_pallas(cols, vals, y, mask, alpha0, w0, idx, *, lam, n,
                             Q, loss: str = "hinge", beta=None,
                             interpret: bool = True):
    """Sparse-cell kernel version of one local SDCA epoch.

    cols/vals: (n_p, k) padded-ELL block; w0: (m_q,) dense primal block;
    idx: (steps,) int32.  ``beta`` (a runtime scalar, may be traced)
    selects the paper's step_mode="beta" denominator; ``lam`` / ``n``
    may also be traced (the fleet's per-tenant path).
    Returns (dalpha, w_final).
    """
    n_p, k = cols.shape
    m_q = w0.shape[0]
    steps = idx.shape[0]
    use_beta = beta is not None
    runtime = not (_static_scalar(lam) and _static_scalar(n))
    params = jnp.stack([
        jnp.asarray(beta if use_beta else 0.0, jnp.float32),
        jnp.asarray(lam, jnp.float32),
        jnp.asarray(n, jnp.float32)])
    kern = functools.partial(
        _kernel,
        lam=None if runtime else float(lam),
        n=None if runtime else int(n),
        Q=int(Q), steps=steps, loss=loss, use_beta=use_beta,
        runtime=runtime)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, k), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, k), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, m_q), lambda h, idx_ref, b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_p, 1), lambda h, idx_ref, b: (0, 0)),
            pl.BlockSpec((1, m_q), lambda h, idx_ref, b: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, m_q), jnp.float32),
            pltpu.VMEM((n_p, 1), jnp.float32),
        ],
    )
    dalpha, w_fin = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_p, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, m_q), jnp.float32),
        ],
        interpret=interpret,
    )(idx, params, cols, vals, y[:, None], mask[:, None], alpha0[:, None],
      w0[None, :])
    return dalpha[:, 0], w_fin[0]
