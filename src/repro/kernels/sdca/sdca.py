"""Pallas TPU kernel: local SDCA epoch (Algorithm 2 inner loop).

TPU adaptation of the paper's random-access CPU loop (DESIGN.md §2):

  * the random coordinate order is materialized ONCE per epoch on the host
    and fed through scalar prefetch (``PrefetchScalarGridSpec``) -- the
    row DMA for step h+1 is issued while step h computes (Pallas
    double-buffers the gathered row blocks);
  * the grid is the step counter (TPU grids execute sequentially, which
    is exactly the dependency structure of dual coordinate ascent);
  * the running primal block w and the dual deltas live in VMEM scratch
    for the whole epoch; nothing but one data row moves per step;
  * outputs are flushed on the last step;
  * the paper's beta step-size variant (step_mode="beta", beta = lam/t)
    rides along as a second scalar-prefetch argument -- beta changes every
    outer iteration, so it must be a runtime input, not a compile-time
    constant.

Supported losses: hinge (closed form), squared.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _static_scalar(v) -> bool:
    """True when ``v`` can be baked into the kernel as a compile-time
    constant (a plain host scalar, not a traced value)."""
    return isinstance(v, (int, float, np.integer, np.floating))


def _kernel(idx_ref,            # scalar prefetch: (steps,) int32
            params_ref,         # scalar prefetch: (3,) f32 [beta, lam, n]
            x_row_ref,          # (1, m_q) gathered row
            y_row_ref,          # (1, 1) label
            mask_row_ref,       # (1, 1)
            alpha_row_ref,      # (1, 1) alpha0[i]
            w0_ref,             # (1, m_q) initial w block
            dalpha_ref,         # out: (n_p, 1)
            w_out_ref,          # out: (1, m_q)
            w_vmem,             # scratch: (1, m_q) f32
            dal_vmem,           # scratch: (n_p, 1) f32
            *, lam, n, Q, steps, loss, use_beta, runtime):
    h = pl.program_id(0)

    @pl.when(h == 0)
    def _init():
        w_vmem[...] = w0_ref[...].astype(jnp.float32)
        dal_vmem[...] = jnp.zeros_like(dal_vmem)

    i = idx_ref[h]
    xi = x_row_ref[0, :].astype(jnp.float32)
    yi = y_row_ref[0, 0].astype(jnp.float32)
    mi = mask_row_ref[0, 0].astype(jnp.float32)
    a_i = alpha_row_ref[0, 0].astype(jnp.float32) + dal_vmem[i, 0]
    # runtime mode (the fleet path): lam / n arrive as traced scalars in
    # the prefetch params vector; static mode bakes the Python constants
    # so the compiled kernel is unchanged
    lam_v = params_ref[1] if runtime else lam
    n_v = params_ref[2] if runtime else n

    w = w_vmem[0, :]
    zloc = jnp.sum(xi * w)
    x_sq = jnp.sum(xi * xi)
    denom = params_ref[0] if use_beta else x_sq
    denom = jnp.maximum(denom, 1e-12)

    if loss == "hinge":
        d = (yi / Q - zloc) * lam_v * n_v / denom
        lo = jnp.where(yi > 0, 0.0, -1.0)
        hi = jnp.where(yi > 0, 1.0, 0.0)
        d = jnp.clip(a_i + d, lo, hi) - a_i
    elif loss == "squared":
        num = yi / Q - a_i / (2.0 * Q) - zloc
        den = 1.0 / (2.0 * Q) + denom / (lam_v * n_v)
        d = num / jnp.maximum(den, 1e-12)
    else:
        raise ValueError(loss)
    d = d * mi

    w_vmem[0, :] = w + (d / (lam_v * n_v)) * xi
    dal_vmem[i, 0] = dal_vmem[i, 0] + d

    @pl.when(h == steps - 1)
    def _flush():
        dalpha_ref[...] = dal_vmem[...]
        w_out_ref[...] = w_vmem[...]


def sdca_epoch_pallas(x, y, mask, alpha0, w0, idx, *, lam, n, Q,
                      loss: str = "hinge", beta=None, interpret: bool = True):
    """Drop-in kernel version of ``ref.sdca_epoch_ref``.

    x: (n_p, m_q) f32; idx: (steps,) int32.  ``beta`` (a runtime scalar,
    may be traced) selects the paper's step_mode="beta" denominator.
    ``lam`` / ``n`` may also be traced (the fleet's per-tenant path);
    they then ride the same scalar-prefetch vector as beta.
    Returns (dalpha, w_final).
    """
    n_p, m_q = x.shape
    steps = idx.shape[0]
    use_beta = beta is not None
    runtime = not (_static_scalar(lam) and _static_scalar(n))
    params = jnp.stack([
        jnp.asarray(beta if use_beta else 0.0, jnp.float32),
        jnp.asarray(lam, jnp.float32),
        jnp.asarray(n, jnp.float32)])
    kern = functools.partial(
        _kernel,
        lam=None if runtime else float(lam),
        n=None if runtime else int(n),
        Q=int(Q), steps=steps, loss=loss, use_beta=use_beta,
        runtime=runtime)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, m_q), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, 1), lambda h, idx_ref, b: (idx_ref[h], 0)),
            pl.BlockSpec((1, m_q), lambda h, idx_ref, b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_p, 1), lambda h, idx_ref, b: (0, 0)),
            pl.BlockSpec((1, m_q), lambda h, idx_ref, b: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, m_q), jnp.float32),
            pltpu.VMEM((n_p, 1), jnp.float32),
        ],
    )
    dalpha, w_fin = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_p, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, m_q), jnp.float32),
        ],
        interpret=interpret,
    )(idx, params, x, y[:, None], mask[:, None], alpha0[:, None],
      w0[None, :])
    return dalpha[:, 0], w_fin[0]
