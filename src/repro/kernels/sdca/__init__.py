from .ops import sdca_epoch
from .ref import sdca_epoch_ref
from .sdca import sdca_epoch_pallas
