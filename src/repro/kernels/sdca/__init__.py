from .ops import sdca_epoch
from .ref import sdca_epoch_ref
from .sdca import sdca_epoch_pallas
from .sparse import sdca_epoch_sparse_pallas

__all__ = ["sdca_epoch", "sdca_epoch_ref", "sdca_epoch_pallas",
           "sdca_epoch_sparse_pallas"]
