"""Jitted public wrapper for the SDCA epoch kernel."""
from __future__ import annotations

from functools import partial

import jax

from .. import default_interpret
from .ref import sdca_epoch_ref
from .sdca import sdca_epoch_pallas


@partial(jax.jit, static_argnames=("lam", "n", "Q", "loss", "backend",
                                   "interpret"))
def sdca_epoch(x, y, mask, alpha0, w0, idx, *, lam, n, Q, loss="hinge",
               backend="pallas", beta=None, interpret=None):
    """One local SDCA epoch on a data block.

    backend="pallas": TPU kernel (interpret-mode on CPU).
    backend="ref": pure-jnp oracle.
    ``beta`` (runtime scalar or None) selects step_mode="beta".
    """
    if backend == "ref":
        return sdca_epoch_ref(x, y, mask, alpha0, w0, idx,
                              lam=lam, n=n, Q=Q, loss=loss, beta=beta)
    if interpret is None:
        interpret = default_interpret()
    return sdca_epoch_pallas(x, y, mask, alpha0, w0, idx,
                             lam=lam, n=n, Q=Q, loss=loss, beta=beta,
                             interpret=interpret)
