"""Jitted public wrapper for the SDCA epoch kernel."""
from __future__ import annotations

from functools import partial

import jax

from .ref import sdca_epoch_ref
from .sdca import sdca_epoch_pallas


@partial(jax.jit, static_argnames=("lam", "n", "Q", "loss", "backend"))
def sdca_epoch(x, y, mask, alpha0, w0, idx, *, lam, n, Q, loss="hinge",
               backend="pallas"):
    """One local SDCA epoch on a data block.

    backend="pallas": TPU kernel (interpret-mode on CPU).
    backend="ref": pure-jnp oracle.
    """
    if backend == "ref":
        return sdca_epoch_ref(x, y, mask, alpha0, w0, idx,
                              lam=lam, n=n, Q=Q, loss=loss)
    return sdca_epoch_pallas(x, y, mask, alpha0, w0, idx,
                             lam=lam, n=n, Q=Q, loss=loss)
