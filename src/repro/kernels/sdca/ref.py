"""Pure-jnp oracle for the local SDCA epoch kernel (hinge / squared).

Identical math to ``repro.core.local.local_sdca`` but taking the
coordinate order as an explicit array (the kernel consumes a
host-materialized order via scalar prefetch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sdca_epoch_ref(x, y, mask, alpha0, w0, idx, *, lam, n, Q,
                   loss: str = "hinge", beta=None):
    """x: (n_p, m_q); idx: (steps,) int32 coordinate order.

    ``beta`` (runtime scalar) replaces the ||x_i||^2 denominator when
    given (the paper's step_mode="beta").  Returns (dalpha (n_p,),
    w_final (m_q,)) in float32.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    x_sq = jnp.sum(x * x, axis=1)

    def body(carry, i):
        w, dalpha = carry
        xi = x[i]
        zloc = xi @ w
        a_i = alpha0[i] + dalpha[i]
        denom = jnp.maximum(x_sq[i] if beta is None else beta, 1e-12)
        if loss == "hinge":
            d = (y[i] / Q - zloc) * lam * n / denom
            lo = jnp.where(y[i] > 0, 0.0, -1.0)
            hi = jnp.where(y[i] > 0, 1.0, 0.0)
            d = jnp.clip(a_i + d, lo, hi) - a_i
        elif loss == "squared":
            num = y[i] / Q - a_i / (2.0 * Q) - zloc
            den = 1.0 / (2.0 * Q) + denom / (lam * n)
            d = num / jnp.maximum(den, 1e-12)
        else:
            raise ValueError(loss)
        d = d * mask[i]
        w = w + (d / (lam * n)) * xi
        dalpha = dalpha.at[i].add(d)
        return (w, dalpha), None

    (w, dalpha), _ = jax.lax.scan(
        body, (w0.astype(jnp.float32), jnp.zeros_like(alpha0,
                                                      jnp.float32)), idx)
    return dalpha, w
