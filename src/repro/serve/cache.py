"""Paged KV cache: one preallocated arena shared by all in-flight sequences.

The arena is split into fixed-size pages of ``page_size`` token slots.  A
host-side :class:`PagePool` hands pages to sequences (all-or-nothing
allocation, explicit free, owner-level eviction for preemption) and a
per-sequence *block table* maps linear token positions to pages:
token ``t`` of a sequence lives at ``(block_table[t // page_size],
t % page_size)``.

Device layout mirrors the model's contiguous cache tree
(``Transformer.make_cache``): one ``{"k", "v"}`` arena of shape
``(n_layers_in_group, num_pages + 1, page_size, n_kv, head_dim)`` per
pattern position / remainder layer.  Row ``num_pages`` is a *trash page*:
masked writes (padding tokens, inactive slots) are routed there instead
of being predicated out, so every scatter is a plain advanced-index
``.at[].set`` -- no one-hot tricks needed off the sharded training path.

Only attention-like mixers (ATTN / LOCAL) are pageable; recurrent mixers
(RWKV / RG-LRU) carry O(1) state and need no paging, and XATTN caches a
static encoder.  ``paged_kinds`` validates a config up front.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp

from ..models.config import ATTN, LOCAL, ModelConfig


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    page_size: int = 16
    num_pages: int = 256

    @property
    def trash_page(self) -> int:
        return self.num_pages

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` token slots."""
        return max(1, -(-n_tokens // self.page_size))


class PagePool:
    """Host-side free-list allocator over ``num_pages`` pages.

    Pages are owned by string/int request ids.  ``alloc`` is atomic
    (all-or-nothing), ``free`` releases every page of an owner (the
    eviction primitive used for preemption), and ``check`` asserts the
    no-double-free / no-orphan invariants.
    """

    def __init__(self, cfg: PagedCacheConfig):
        self.cfg = cfg
        self._free: List[int] = list(range(cfg.num_pages - 1, -1, -1))
        self._owned: Dict[object, List[int]] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    def pages(self, owner) -> List[int]:
        return list(self._owned.get(owner, ()))

    def owners(self):
        return list(self._owned)

    def alloc(self, owner, n: int = 1) -> Optional[List[int]]:
        """Give ``owner`` ``n`` more pages, or None (and no change) if the
        pool cannot satisfy the request."""
        if n < 0:
            raise ValueError(f"alloc n={n}")
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._owned.setdefault(owner, []).extend(got)
        return got

    def free(self, owner) -> int:
        """Release every page of ``owner``; returns the count.

        Raises KeyError if ``owner`` holds nothing (double free)."""
        if owner not in self._owned:
            raise KeyError(f"free of unknown owner {owner!r} (double free?)")
        pages = self._owned.pop(owner)
        self._free.extend(pages)
        return len(pages)

    def check(self):
        """Invariants: free + owned partition [0, num_pages); no dups."""
        owned = [p for ps in self._owned.values() for p in ps]
        seen = self._free + owned
        assert len(seen) == len(set(seen)), "duplicate page id"
        assert set(seen) == set(range(self.cfg.num_pages)), \
            "orphaned or out-of-range page"


# ---------------------------------------------------------------------------
# device arenas
# ---------------------------------------------------------------------------

def paged_kinds(cfg: ModelConfig) -> List[str]:
    """The model's mixer kinds, validated as pageable."""
    bad = sorted(set(k for k in cfg.pattern if k not in (ATTN, LOCAL)))
    if bad:
        raise NotImplementedError(
            f"paged serving supports attention mixers only; {cfg.name} "
            f"has {bad}")
    if cfg.kv_cache_dtype == "int8":
        raise NotImplementedError(
            "paged serving stores the compute dtype; int8 paged pages are "
            "a future optimization")
    if cfg.embed_input != "tokens":
        raise NotImplementedError("paged serving needs a token frontend")
    return list(cfg.pattern)


def _arena(cfg: ModelConfig, n_layers: int, pc: PagedCacheConfig):
    shape = (n_layers, pc.num_pages + 1, pc.page_size, cfg.n_kv, cfg.hd)
    return {"k": jnp.zeros(shape, cfg.cdtype),
            "v": jnp.zeros(shape, cfg.cdtype)}


def make_paged_arenas(cfg: ModelConfig, pc: PagedCacheConfig):
    """Arena tree mirroring ``Transformer.make_cache`` structure."""
    paged_kinds(cfg)
    n_full, n_rem = cfg.n_periods()
    return {
        "periods": [_arena(cfg, n_full, pc) for _ in cfg.pattern]
        if n_full else [],
        "remainder": [_arena(cfg, 1, pc) for _ in range(n_rem)],
    }


def write_prompt_pages(arenas, prefill_cache, bt_row, true_len,
                       pc: PagedCacheConfig):
    """Scatter a linear prefill cache into the paged arenas.

    ``prefill_cache`` is the tree returned by ``Transformer.prefill(...,
    linear_cache=True)`` for a batch of ONE sequence: per layer group,
    k/v of shape ``(n_layers, 1, S, n_kv, hd)`` holding the prompt's
    full-length keys/values.  Tokens ``t < true_len`` go to
    ``(bt_row[t // page_size], t % page_size)``; padding tokens go to the
    trash page.  jit-friendly (``true_len`` may be traced).
    """
    S = None
    for group in prefill_cache["periods"] + prefill_cache["remainder"]:
        S = group["k"].shape[2]
        break
    if S is None:
        return arenas
    t = jnp.arange(S)
    pidx = jnp.where(t < true_len, bt_row[t // pc.page_size], pc.trash_page)
    off = t % pc.page_size

    def scat(arena, kv):
        # arena: (n, NP+1, ps, KV, hd); kv[:, 0]: (n, S, KV, hd)
        return arena.at[:, pidx, off].set(kv[:, 0].astype(arena.dtype))

    def group_scat(arena_g, cache_g):
        return {"k": scat(arena_g["k"], cache_g["k"]),
                "v": scat(arena_g["v"], cache_g["v"])}

    return {
        "periods": [group_scat(a, c) for a, c in
                    zip(arenas["periods"], prefill_cache["periods"])],
        "remainder": [group_scat(a, c) for a, c in
                      zip(arenas["remainder"], prefill_cache["remainder"])],
    }
