"""Doubly-distributed batched scoring for the paper's linear models.

Serving analogue of Algorithm 1's primal-dual map: at inference the
request batch shards over the paper's "data" axis (observations) and the
weight vector over the "model" axis (features), so a margin
``x . w`` is a *local* partial product per device followed by one
``psum`` over the "model" axis -- the same P x Q layout the training
path uses (repro/core/d3ca.py), pointed at traffic instead of epochs.

``LinearScorer`` adds the serving wrapper: zero-padding to the grid,
fixed-size row buckets (one compiled program regardless of request
size), loss-appropriate links (sign / sigmoid), and rows/s counters.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.util import shard_map


def make_score_fn(mesh, *, data_axis: str = "data",
                  model_axis: str = "model"):
    """Jitted ``(x (B, m), w (m,)) -> margins (B,)`` on a P x Q mesh.

    x is sharded (data, model) -- each device holds one (B/P, m/Q)
    request block; w is sharded (model,).  B % P == 0 and m % Q == 0
    are the caller's job (LinearScorer pads).
    """

    def cell(x_b, w_b):
        return jax.lax.psum(x_b @ w_b, model_axis)

    fn = shard_map(cell, mesh,
                   in_specs=(P(data_axis, model_axis), P(model_axis)),
                   out_specs=P(data_axis))
    return jax.jit(fn)


def _ceil_to(x: int, k: int) -> int:
    return (x + k - 1) // k * k


class LinearScorer:
    """High-throughput scoring of a trained linear model ``w``.

    ``loss`` picks the link: "logistic" -> P(y=1) = sigmoid(margin);
    "hinge"/"squared" -> +-1 labels = sign(margin).
    """

    def __init__(self, w, mesh=None, *, loss: str = "hinge",
                 bucket: Optional[int] = None, clock=time.perf_counter):
        self.mesh = mesh
        self.loss = loss
        self.clock = clock
        self.rows_scored = 0
        self.seconds = 0.0
        if mesh is not None:
            self.P = int(mesh.shape["data"])
            self.Q = int(mesh.shape["model"])
            self._m_pad = _ceil_to(len(np.asarray(w)), self.Q)
            self._fn = make_score_fn(mesh)
        else:
            self.P, self.Q = 1, 1
            self._m_pad = len(np.asarray(w))
            self._fn = jax.jit(lambda x, wv: x @ wv)
        self.m = len(np.asarray(w))
        self.w = self._pad(w)
        self.w_version = 0
        # row bucket: fixed compiled shape; default one grid row per call
        self.bucket = bucket if bucket is not None else max(self.P, 64)
        self.bucket = _ceil_to(self.bucket, self.P)

    def _pad(self, w):
        wp = np.zeros((self._m_pad,), np.float32)
        wp[: self.m] = np.asarray(w, np.float32)
        return jnp.asarray(wp)

    def update_weights(self, w, version: Optional[int] = None):
        """Swap in a new model snapshot without recompiling.

        The padded device array is built first and the ``self.w``
        reference swapped in one assignment, so a concurrent
        :meth:`score` call always reads a complete weight vector --
        either the old snapshot or the new one, never a mix.  This is
        the serving half of the online service's atomic hand-off.

        Args:
          w: (m,) new weights (same m the scorer was built with).
          version: optional snapshot version recorded as
            ``self.w_version`` for staleness introspection.

        Raises:
          ValueError: on a length mismatch with the compiled m.
        """
        if len(np.asarray(w)) != self.m:
            raise ValueError(f"expected ({self.m},) weights; got "
                             f"{np.asarray(w).shape}")
        w_new = self._pad(w)         # build off to the side...
        self.w = w_new               # ...then one atomic reference swap
        if version is not None:
            self.w_version = version

    def score(self, X) -> np.ndarray:
        """Margins x . w for a (B, m) request batch (any B)."""
        X = np.asarray(X, np.float32)
        if X.ndim != 2 or X.shape[1] != self.m:
            raise ValueError(f"expected (B, {self.m}); got {X.shape}")
        B = X.shape[0]
        out = np.empty((B,), np.float32)
        t0 = self.clock()
        w = self.w    # one snapshot read: a whole batch scores one version
        for lo in range(0, B, self.bucket):
            chunk = X[lo: lo + self.bucket]
            pad = np.zeros((self.bucket, self._m_pad), np.float32)
            pad[: len(chunk), : self.m] = chunk
            margins = np.asarray(
                jax.block_until_ready(self._fn(jnp.asarray(pad), w)))
            out[lo: lo + len(chunk)] = margins[: len(chunk)]
        self.seconds += self.clock() - t0
        self.rows_scored += B
        return out

    def predict(self, X) -> np.ndarray:
        """Labels (+-1) or, for logistic loss, P(y = +1)."""
        margins = self.score(X)
        if self.loss == "logistic":
            return 1.0 / (1.0 + np.exp(-margins))
        return np.where(margins >= 0.0, 1.0, -1.0).astype(np.float32)

    @property
    def rows_per_sec(self) -> float:
        return self.rows_scored / self.seconds if self.seconds > 0 else 0.0
