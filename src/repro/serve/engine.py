"""Continuous-batching inference engine over the paged KV cache.

The step loop interleaves *prefill* of newly admitted requests with
*decode* of in-flight ones: a finished sequence's slot and pages are
released at the end of the step and backfilled from the queue at the top
of the next, so decode batches stay as full as the queue allows -- the
serving analogue of CoCoA's "maximize local work per communication
round" (no device idles while requests wait).

Scheduling state lives on the host (slot table, block tables, lengths);
device state is the paged arena pytree threaded through two jitted
functions (one prefill per bucket length, one decode for the fixed
``max_slots`` batch).  Greedy decoding is token-for-token identical to
the static-batch loop (tests/test_serve.py).

Admission control:
  * requests longer than ``max_seq_len`` (prompt + max_new_tokens) or
    beyond ``max_queue`` are rejected at submit();
  * ``reserve_pages=True`` (default) admits a request only when its
    *worst-case* page count fits alongside all current reservations --
    growth can then never fail and no preemption happens;
  * ``reserve_pages=False`` admits on prompt-size fit and handles page
    exhaustion during decode by *preempting* the youngest sequence:
    its pages are freed (evicted) and the request is requeued at the
    front, to be replayed later.  Per-request seeds make the replayed
    sample stream identical.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.serve import RequestMetrics
from repro.obs.trace import as_tracer

from .cache import PagePool, PagedCacheConfig, make_paged_arenas, \
    paged_kinds, write_prompt_pages
from .sampling import SamplingParams, params_arrays, sample_tokens


@dataclasses.dataclass
class Request:
    rid: object
    prompt: np.ndarray              # (len,) int32 token ids
    max_new_tokens: int = 16
    sampling: SamplingParams = SamplingParams()
    stop_token: Optional[int] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 4
    page_size: int = 16
    num_pages: int = 256
    max_seq_len: int = 512          # prompt + generated, per sequence
    max_queue: int = 1024
    reserve_pages: bool = True


@dataclasses.dataclass
class _Slot:
    rid: object
    request: Request
    kv_len: int                     # tokens whose KV is in the arena
    generated: List[int]
    admit_seq: int                  # admission order; eviction priority


class InferenceEngine:
    def __init__(self, model, params, cfg: EngineConfig = EngineConfig(),
                 clock=time.perf_counter, tracer=None, registry=None,
                 monitor=None):
        paged_kinds(model.cfg)      # raises for unsupported archs
        self.model = model
        self.params = params
        self.cfg = cfg
        self.pc = PagedCacheConfig(cfg.page_size, cfg.num_pages)
        self.max_pages = self.pc.pages_for(cfg.max_seq_len)
        self.pool = PagePool(self.pc)
        self.arenas = make_paged_arenas(model.cfg, self.pc)
        self.metrics = RequestMetrics(clock, registry=registry)
        #: optional repro.obs Tracer; spans the admission/prefill/decode
        #: phases of every step and marks preempt/finish/reject instants
        self.tracer = as_tracer(tracer)
        #: optional repro.obs HealthMonitor; poll()ed once per engine
        #: step (rate-limited inside the monitor, so per-step cost is a
        #: clock read when not due)
        self.monitor = monitor

        self.queue: collections.deque = collections.deque()
        self.slots: List[Optional[_Slot]] = [None] * cfg.max_slots
        # block tables, trash-initialized; mirrored to device on change
        self._bt = np.full((cfg.max_slots, self.max_pages),
                           self.pc.trash_page, np.int32)
        self.outputs: Dict[object, np.ndarray] = {}
        self._live: set = set()         # rids queued or in a slot
        self._admit_seq = 0
        self._reserved_pages = 0
        self._greedy = SamplingParams()

        # buffer donation is a no-op on CPU and warns; skip it there
        donate = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (1,)}
        self._decode = jax.jit(self._decode_fn, **donate)
        # greedy fast path: when every active slot is temperature-0 the
        # step skips the sampling machinery (full-vocab sort + scatters)
        self._decode_greedy = jax.jit(self._decode_greedy_fn, **donate)
        # one jitted prefill; jax caches a compilation per bucket length
        donate_p = {} if jax.default_backend() == "cpu" else \
            {"donate_argnums": (3,)}
        self._prefill = jax.jit(self._prefill_fn, **donate_p)

    # ------------------------------------------------------------------
    # jitted device functions
    # ------------------------------------------------------------------
    def _decode_fn(self, params, arenas, tokens, bt, lengths, active,
                   temps, tks, tps, seeds, steps):
        logits, arenas = self.model.decode_step_paged(
            params, arenas, {"tokens": tokens}, bt, lengths, active)
        nxt = sample_tokens(logits[:, 0], temps, tks, tps, seeds, steps)
        return nxt, arenas

    def _decode_greedy_fn(self, params, arenas, tokens, bt, lengths, active):
        logits, arenas = self.model.decode_step_paged(
            params, arenas, {"tokens": tokens}, bt, lengths, active)
        return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32), arenas

    def _prefill_fn(self, params, tokens, true_len, arenas, bt_row,
                    temps, tks, tps, seeds, steps):
        S = tokens.shape[1]
        logits, cache = self.model.prefill(
            params, {"tokens": tokens}, S, last_pos=true_len - 1,
            linear_cache=True)
        arenas = write_prompt_pages(arenas, cache, bt_row, true_len, self.pc)
        nxt = sample_tokens(logits[:, 0], temps, tks, tps, seeds, steps)
        return nxt[0], arenas

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _reject(self, req: Request, reason: str) -> bool:
        self.metrics.rejections += 1
        self.tracer.instant("reject", rid=str(req.rid), reason=reason)
        return False

    def submit(self, req: Request) -> bool:
        """Queue a request; False (and a rejection count) if refused."""
        total = len(req.prompt) + req.max_new_tokens
        if total > self.cfg.max_seq_len or \
                self.pc.pages_for(total) > self.cfg.num_pages:
            return self._reject(req, "too_long")
        if len(self.queue) >= self.cfg.max_queue:
            return self._reject(req, "queue_full")
        # rids key the page pool and the output dict: a duplicate would
        # merge two requests' pages under one owner (double free /
        # cross-request KV reuse on finish)
        if req.rid in self._live or req.rid in self.outputs:
            return self._reject(req, "duplicate_rid")
        self._live.add(req.rid)
        self.queue.append(req)
        self.metrics.start_request(req.rid, len(req.prompt))
        return True

    def _bucket(self, n: int) -> int:
        return self.pc.pages_for(n) * self.cfg.page_size

    def _try_admit_one(self) -> bool:
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        if not free_slots or not self.queue:
            return False
        req = self.queue[0]
        need_now = self.pc.pages_for(len(req.prompt))
        need_max = self.pc.pages_for(len(req.prompt) + req.max_new_tokens)
        if self.cfg.reserve_pages:
            if self._reserved_pages + need_max > self.cfg.num_pages:
                return False
        elif self.pool.n_free < need_now:
            return False
        self.queue.popleft()
        pages = self.pool.alloc(req.rid, need_now)
        assert pages is not None
        if self.cfg.reserve_pages:
            self._reserved_pages += need_max

        i = free_slots[0]
        bt_row = np.full((self.max_pages,), self.pc.trash_page, np.int32)
        bt_row[: len(pages)] = pages
        self._bt[i] = bt_row

        plen = len(req.prompt)
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        sp = params_arrays([req.sampling], [0])
        with self.tracer.span("prefill", rid=str(req.rid), prompt_len=plen,
                              bucket=bucket):
            first, self.arenas = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(plen, jnp.int32),
                self.arenas, jnp.asarray(bt_row), *sp)
            first = int(first)      # device sync closes the span honestly
        self.metrics.prefills += 1
        self.metrics.first_token(req.rid)

        slot = _Slot(rid=req.rid, request=req, kv_len=plen,
                     generated=[first], admit_seq=self._admit_seq)
        self._admit_seq += 1
        self.slots[i] = slot
        self._maybe_finish(i, first)
        return True

    # ------------------------------------------------------------------
    # growth / eviction
    # ------------------------------------------------------------------
    def _preempt(self, i: int):
        """Evict slot ``i``: free its pages, requeue its request (front)."""
        slot = self.slots[i]
        self.pool.free(slot.rid)
        if self.cfg.reserve_pages:
            self._reserved_pages -= self.pc.pages_for(
                len(slot.request.prompt) + slot.request.max_new_tokens)
        self._bt[i] = self.pc.trash_page
        self.slots[i] = None
        self.queue.appendleft(slot.request)
        self.metrics.preemptions += 1
        self.tracer.instant("preempt", rid=str(slot.rid), slot=i)

    def _grow(self):
        """Ensure every active slot has a page for its next write."""
        order = sorted((s.admit_seq, i) for i, s in enumerate(self.slots)
                       if s is not None)
        for _, i in order:
            slot = self.slots[i]
            if slot is None:
                continue
            n_owned = len(self.pool.pages(slot.rid))
            if slot.kv_len < n_owned * self.cfg.page_size:
                continue
            while True:
                got = self.pool.alloc(slot.rid, 1)
                if got is not None:
                    self._bt[i, n_owned] = got[0]
                    break
                # page exhaustion: evict the youngest active sequence
                victims = [(s.admit_seq, j) for j, s in
                           enumerate(self.slots) if s is not None]
                _, j = max(victims)
                self._preempt(j)
                if j == i:          # evicted ourselves; nothing to grow
                    break

    # ------------------------------------------------------------------
    # finish / retire
    # ------------------------------------------------------------------
    def _maybe_finish(self, i: int, last_token: int) -> bool:
        slot = self.slots[i]
        req = slot.request
        done = len(slot.generated) >= req.max_new_tokens or \
            (req.stop_token is not None and last_token == req.stop_token)
        if not done:
            return False
        self.outputs[slot.rid] = np.asarray(slot.generated, np.int32)
        self._live.discard(slot.rid)
        self.metrics.finish(slot.rid, len(slot.generated))
        self.tracer.instant("finish", rid=str(slot.rid),
                            n_generated=len(slot.generated))
        self.pool.free(slot.rid)
        if self.cfg.reserve_pages:
            self._reserved_pages -= self.pc.pages_for(
                len(req.prompt) + req.max_new_tokens)
        self._bt[i] = self.pc.trash_page
        self.slots[i] = None
        return True

    # ------------------------------------------------------------------
    # the step loop
    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit + grow + one decode step.  False when fully idle."""
        with self.tracer.span("engine_step"):
            out = self._step_inner()
        if self.monitor is not None:
            self.monitor.poll()
        return out

    def _step_inner(self) -> bool:
        with self.tracer.span("admission"):
            while self._try_admit_one():
                pass
            self._grow()

        active_idx = [i for i, s in enumerate(self.slots) if s is not None]
        if not active_idx:
            return bool(self.queue)

        B = self.cfg.max_slots
        tokens = np.zeros((B, 1), np.int32)
        lengths = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        sp_list = [self._greedy] * B
        steps = [0] * B
        for i in active_idx:
            s = self.slots[i]
            tokens[i, 0] = s.generated[-1]
            lengths[i] = s.kv_len
            active[i] = True
            sp_list[i] = s.request.sampling
            steps[i] = len(s.generated)

        with self.tracer.span("decode_step", batch=len(active_idx)):
            if all(self.slots[i].request.sampling.temperature <= 0.0
                   for i in active_idx):
                nxt, self.arenas = self._decode_greedy(
                    self.params, self.arenas, jnp.asarray(tokens),
                    jnp.asarray(self._bt), jnp.asarray(lengths),
                    jnp.asarray(active))
            else:
                sp = params_arrays(sp_list, steps)
                nxt, self.arenas = self._decode(
                    self.params, self.arenas, jnp.asarray(tokens),
                    jnp.asarray(self._bt), jnp.asarray(lengths),
                    jnp.asarray(active), *sp)
            nxt = np.asarray(nxt)   # device sync closes the span honestly
        self.metrics.decode_steps += 1

        for i in active_idx:
            s = self.slots[i]
            s.kv_len += 1
            tok = int(nxt[i])
            s.generated.append(tok)
            self._maybe_finish(i, tok)
        return True

    def run(self, requests) -> Dict[object, np.ndarray]:
        """Submit everything, drive the loop to completion, return
        {rid: generated token ids}; read ``self.metrics`` for stats.

        ``outputs`` and ``metrics`` accumulate across calls (requests
        may also be submit()ed before run); for per-batch numbers on a
        reused engine, swap in a fresh ``RequestMetrics`` first and
        select outputs by rid -- benchmarks/serve_bench.py does this."""
        for r in requests:
            self.submit(r)
        while self.step():
            pass
        return dict(self.outputs)
