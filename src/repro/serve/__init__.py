"""repro.serve -- continuous-batching inference engine.

The serving analogue of the paper's P x Q doubly distributed layout:
batched requests shard over the "data" axis, model state over the
"model" axis.  Continuous batching keeps devices busy between requests
(the CoCoA design rule -- maximize local work per communication round --
applied to inference).

Modules:
  * ``cache``    -- paged KV-cache pool: fixed-size blocks, per-sequence
                    block tables, alloc/free/eviction over one arena
  * ``engine``   -- continuous-batching scheduler (queue, admission
                    control, prefill/decode interleave, backfill)
  * ``sampling`` -- greedy / temperature / top-k / top-p with
                    per-request seeds
  * ``scoring``  -- doubly-distributed batched x.w scoring for the
                    paper's trained linear models
  * ``metrics``  -- DEPRECATED shim over :mod:`repro.obs.serve` (the
                    unified telemetry subsystem owns serving metrics:
                    ``RequestMetrics`` + the shared metrics registry)
"""
from repro.obs.metrics import percentiles
from repro.obs.serve import RequestMetrics

from .cache import PagePool, PagedCacheConfig, make_paged_arenas
from .engine import EngineConfig, InferenceEngine, Request
from .sampling import SamplingParams, sample_tokens
from .scoring import LinearScorer, make_score_fn

__all__ = [
    "PagePool", "PagedCacheConfig", "make_paged_arenas",
    "EngineConfig", "InferenceEngine", "Request",
    "RequestMetrics", "ServeMetrics", "percentiles",
    "SamplingParams", "sample_tokens",
    "LinearScorer", "make_score_fn",
]


def __getattr__(name):
    # lazy: importing repro.serve must stay silent; touching the legacy
    # name (not the package) is what triggers the DeprecationWarning
    if name == "ServeMetrics":
        from .metrics import ServeMetrics
        return ServeMetrics
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
