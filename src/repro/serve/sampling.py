"""Token sampling: greedy / temperature / top-k / top-p, per-request seeds.

All parameters are *data*, not static arguments, so one jitted
``sample_tokens`` serves every slot of a continuous batch regardless of
each request's settings: temperature 0 selects the greedy branch
per-row, ``top_k <= 0`` disables top-k, ``top_p >= 1`` disables top-p.

Reproducibility: each request carries its own integer ``seed``; token
``i`` of that request is drawn with ``fold_in(fold_in(base, seed), i)``,
so a request's stream is independent of which slot it runs in, what else
shares the batch, and whether it was preempted and replayed.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0    # 0 -> greedy argmax
    top_k: int = 0              # <= 0 -> no top-k filtering
    top_p: float = 1.0          # >= 1 -> no nucleus filtering
    seed: int = 0


def _filter_logits(logits, top_k, top_p):
    """Apply top-k / top-p masks to a (V,) logit row (all args traced)."""
    V = logits.shape[-1]
    order = jnp.argsort(-logits)                    # descending
    srt = logits[order]
    ranks = jnp.zeros((V,), jnp.int32).at[order].set(jnp.arange(V))
    keep = (ranks < top_k) | (top_k <= 0)
    probs = jax.nn.softmax(srt)
    # nucleus: keep tokens whose *preceding* cumulative mass is < top_p
    # (the argmax token always survives: its preceding mass is 0)
    cum_before = jnp.cumsum(probs) - probs
    keep &= jnp.zeros((V,), bool).at[order].set(cum_before < top_p)
    return jnp.where(keep, logits, NEG_INF)


def _sample_one(logits, temperature, top_k, top_p, seed, step):
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(0), seed),
                             step)
    scaled = logits / jnp.maximum(temperature, 1e-6)
    drawn = jax.random.categorical(
        key, _filter_logits(scaled, top_k, top_p)).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, drawn)


def sample_tokens(logits, temperatures, top_ks, top_ps, seeds, steps):
    """Sample one token per row.

    logits: (B, V) float32; temperatures/top_ps: (B,) float32;
    top_ks/seeds/steps: (B,) int32.  ``steps`` is the per-request count
    of tokens already drawn (the fold_in counter).  Returns (B,) int32.
    """
    return jax.vmap(_sample_one)(logits, temperatures, top_ks, top_ps,
                                 seeds, steps)


def params_arrays(params_list, steps):
    """Stack per-slot SamplingParams (+ step counters) into device arrays."""
    import numpy as np
    temps = np.asarray([p.temperature for p in params_list], np.float32)
    tks = np.asarray([p.top_k for p in params_list], np.int32)
    tps = np.asarray([p.top_p for p in params_list], np.float32)
    seeds = np.asarray([p.seed for p in params_list], np.int32)
    return (jnp.asarray(temps), jnp.asarray(tks), jnp.asarray(tps),
            jnp.asarray(seeds), jnp.asarray(np.asarray(steps, np.int32)))
