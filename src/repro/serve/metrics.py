"""DEPRECATED: moved to :mod:`repro.obs.serve` (telemetry subsystem).

``ServeMetrics`` is now :class:`repro.obs.serve.RequestMetrics`, which
writes every aggregate through a :class:`repro.obs.metrics.Registry`
(one ``snapshot()`` schema shared with solver telemetry), adds p90 to
the default percentile set, and makes ``summary()`` skip unfinished
requests instead of raising on a cut-short trace.

This shim keeps the old import path working (same engine-facing API:
``start_request`` / ``first_token`` / ``finish`` / ``summary`` and the
``preemptions`` / ``rejections`` / ``decode_steps`` / ``prefills``
counters) and warns on import; it will be removed once nothing imports
it.
"""
from __future__ import annotations

import warnings

from repro.obs.metrics import percentiles  # noqa: F401
from repro.obs.serve import RequestMetrics

warnings.warn(
    "repro.serve.metrics is deprecated; use repro.obs.serve."
    "RequestMetrics (same lifecycle API, registry-backed, p90 in the "
    "default percentiles) and repro.obs.metrics.percentiles",
    DeprecationWarning, stacklevel=2)


class ServeMetrics(RequestMetrics):
    """Legacy name for :class:`repro.obs.serve.RequestMetrics`."""
