"""Serving metrics: tokens/s, time-to-first-token, latency percentiles.

Pure host-side bookkeeping -- the engine calls ``start_request`` /
``first_token`` / ``finish`` around its step loop and reads ``summary()``
at the end.  The clock is injectable for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np


def percentiles(xs, qs=(50, 99)):
    """{f"p{q}": value} over ``xs`` (empty input -> zeros)."""
    if len(xs) == 0:
        return {f"p{q}": 0.0 for q in qs}
    arr = np.asarray(xs, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


@dataclasses.dataclass
class _RequestRecord:
    arrival: float
    n_prompt: int
    first_token: Optional[float] = None
    finish: Optional[float] = None
    n_generated: int = 0


class ServeMetrics:
    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self._req: Dict[object, _RequestRecord] = {}
        self.preemptions = 0
        self.rejections = 0
        self.decode_steps = 0
        self.prefills = 0
        self._t0: Optional[float] = None
        self._t1: Optional[float] = None

    # ---- per-request lifecycle ----
    def start_request(self, rid, n_prompt, arrival=None):
        t = self.clock() if arrival is None else arrival
        if self._t0 is None:
            self._t0 = t
        # re-registration after preemption keeps the ORIGINAL arrival
        if rid not in self._req:
            self._req[rid] = _RequestRecord(arrival=t, n_prompt=n_prompt)

    def first_token(self, rid):
        rec = self._req[rid]
        if rec.first_token is None:
            rec.first_token = self.clock()

    def finish(self, rid, n_generated):
        rec = self._req[rid]
        rec.finish = self.clock()
        rec.n_generated = n_generated
        self._t1 = rec.finish

    # ---- aggregates ----
    def _done(self) -> List[_RequestRecord]:
        return [r for r in self._req.values() if r.finish is not None]

    @property
    def generated_tokens(self) -> int:
        return sum(r.n_generated for r in self._done())

    @property
    def elapsed(self) -> float:
        if self._t0 is None or self._t1 is None:
            return 0.0
        return max(self._t1 - self._t0, 1e-9)

    def tokens_per_sec(self) -> float:
        return self.generated_tokens / self.elapsed if self._done() else 0.0

    def summary(self) -> dict:
        done = self._done()
        ttft = [r.first_token - r.arrival for r in done
                if r.first_token is not None]
        lat = [r.finish - r.arrival for r in done]
        return {
            "requests_finished": len(done),
            "generated_tokens": self.generated_tokens,
            "elapsed_s": self.elapsed,
            "tokens_per_sec": self.tokens_per_sec(),
            "ttft_s": percentiles(ttft),
            "latency_s": percentiles(lat),
            "prefills": self.prefills,
            "decode_steps": self.decode_steps,
            "preemptions": self.preemptions,
            "rejections": self.rejections,
        }
