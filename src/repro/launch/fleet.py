"""Multi-tenant fleet CLI over :mod:`repro.fleet`.

Solve many independent tenant problems in one batched program:

  PYTHONPATH=src python -m repro.launch.fleet \\
      --solver d3ca --tenants 8 --n 256 --m 64 --mesh 2x2 --iters 6

  # mixed shapes: every other tenant gets 50% more rows, so the
  # scheduler packs two shape buckets and drives one batched solve per
  # bucket (retracing is bounded by the bucket count, not by T)
  PYTHONPATH=src python -m repro.launch.fleet \\
      --tenants 8 --shape-mix --metrics

  # the shard_map mesh path (one block per device, all tenants share
  # each collective round); fake the device grid on CPU:
  PYTHONPATH=src python -m repro.launch.fleet \\
      --engine shard_map --mesh 4x2 --force-host-devices 8

  # several rounds over the same tenants: round r warm-starts every
  # tenant from its round r-1 result (the scheduler's warm registry),
  # and --publish-snapshots pushes each tenant's iterates into its own
  # online SnapshotBook + LinearScorer after every round
  PYTHONPATH=src python -m repro.launch.fleet \\
      --tenants 4 --rounds 3 --publish-snapshots

Prints one line per tenant per round and a final JSON summary.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_mesh(s: str):
    try:
        p, q = s.lower().split("x")
        return int(p), int(q)
    except ValueError:
        raise argparse.ArgumentTypeError(f"--mesh expects PxQ, got {s!r}")


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.fleet",
        description="Multi-tenant batched solves (one compiled step for "
                    "T tenants)")
    ap.add_argument("--solver", default="d3ca",
                    help="d3ca | radisa | sfk | admm")
    ap.add_argument("--engine", default="simulated",
                    choices=["simulated", "shard_map", "sync"],
                    help="simulated = vmap grid on one device; shard_map "
                         "(alias: sync) = one block per device.  The "
                         "async/overlap engines are rejected by the fleet "
                         "path (per-build ring state has no tenant axis)")
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"],
                    help="cell-local solver backend")
    ap.add_argument("--block-format", default="dense",
                    choices=["dense", "sparse"])
    ap.add_argument("--mesh", type=_parse_mesh, default=(2, 2),
                    metavar="PxQ", help="grid shape, e.g. 2x2")
    ap.add_argument("--tenants", type=int, default=8, metavar="T")
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--density", type=float, default=0.05,
                    help="nonzero fraction for --block-format sparse data")
    ap.add_argument("--loss", default="hinge",
                    choices=["hinge", "squared", "logistic"])
    ap.add_argument("--lam", type=float, default=1.0,
                    help="base regularization; tenant i uses "
                         "lam * 0.5^(i mod 3)")
    ap.add_argument("--iters", type=int, default=6)
    ap.add_argument("--tol", type=float, default=None,
                    help="per-tenant early stopping (converged tenants "
                         "freeze exactly; the batch stops when all froze)")
    ap.add_argument("--check-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=1,
                    help="resubmit every tenant this many times; round "
                         "r warm-starts from round r-1 (warm registry)")
    ap.add_argument("--max-tenants", type=int, default=None,
                    help="cap tenants per batched solve (bigger buckets "
                         "split into chunks)")
    ap.add_argument("--shape-mix", action="store_true",
                    help="give every other tenant 50%% more rows, "
                         "exercising the scheduler's shape buckets")
    ap.add_argument("--publish-snapshots", action="store_true",
                    help="publish every tenant result into a per-tenant "
                         "online SnapshotBook and refresh its "
                         "LinearScorer (the serving hand-off)")
    ap.add_argument("--force-host-devices", type=int, default=None,
                    help="fake N CPU devices (required before jax init "
                         "for --engine shard_map on a laptop)")
    ap.add_argument("--json-out", default=None,
                    help="write the summary JSON here as well")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="trace the run (fleet/pack, fleet/step, "
                         "fleet/unpack spans) and write Chrome-trace "
                         "JSON here")
    ap.add_argument("--metrics", action="store_true",
                    help="record fleet gauges (tenants per bucket, "
                         "active tenants, per-tenant rel_opt) and print "
                         "the registry snapshot in the summary JSON")
    ap.add_argument("--min-tenants", type=int, default=2,
                    help="--health: WARN when a shape bucket runs with "
                         "fewer tenants than this (starved bucket)")
    from .obs import add_obs_flags
    add_obs_flags(ap)
    return ap


def _report_round(r, problems, results, tenants, books, args):
    """Record + print one round's per-tenant lines."""
    for p in problems:
        res = results[p.tenant_id]
        entry = {
            "tenant": p.tenant_id, "lam": p.lam,
            "n": p.n, "m": p.m, "iters": res.iters,
            "converged": res.converged,
            "objective": (res.history[-1]["objective"]
                          if res.history else None),
        }
        if args.publish_snapshots and p.tenant_id in books:
            entry["snapshot_version"] = \
                books[p.tenant_id].current().version
        tenants[p.tenant_id] = entry
        obj = (f"f={entry['objective']:.6f}"
               if entry["objective"] is not None else "f=?")
        print(f"  round={r} {p.tenant_id:>10} lam={p.lam:<8g} "
              f"n={p.n} iters={res.iters} {obj}"
              + (" converged" if res.converged else ""))


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    ap = build_parser()
    args = ap.parse_args(argv)

    if args.force_host_devices:
        if "jax" in sys.modules:
            print("warning: jax already initialized; "
                  "--force-host-devices has no effect", file=sys.stderr)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.force_host_devices}").strip()

    # jax (and everything that imports it) only after the device forcing
    from repro.core import get_solver
    from repro.data import make_sparse_svm_data, make_svm_data
    from repro.fleet import FleetProblem, FleetScheduler

    P, Q = args.mesh
    sparse_fmt = args.block_format == "sparse"

    problems = []
    for i in range(args.tenants):
        n = args.n + (args.n // 2 if args.shape_mix and i % 2 else 0)
        seed = args.seed + i
        if sparse_fmt:
            X, y = make_sparse_svm_data(n, args.m, density=args.density,
                                        seed=seed)
        else:
            X, y = make_svm_data(n, args.m, seed=seed)
        problems.append(FleetProblem(
            tenant_id=f"tenant{i}", loss_name=args.loss, X=X, y=y,
            lam=args.lam * 0.5 ** (i % 3), seed=seed))

    cls = get_solver(args.solver)
    cfg_kw = {"lam": args.lam, "outer_iters": args.iters}
    if args.solver == "admm":
        cfg_kw["rho"] = args.lam
    cfg = cls.config_cls(**cfg_kw)

    tracer = registry = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics:
        from repro.obs import Registry
        registry = Registry()
    from .obs import build_plane
    plane_rules = None
    if args.health:
        from repro.obs import fleet_rules
        plane_rules = fleet_rules(min_tenants=args.min_tenants)
    plane = build_plane(args, rules=plane_rules, registry=registry,
                        meta={"cli": "fleet", "solver": args.solver,
                              "engine": args.engine,
                              "tenants": args.tenants})
    registry = plane.registry if plane.active else registry

    books, scorers = {}, {}

    def on_result(tid, res):
        if not args.publish_snapshots:
            return
        import numpy as np
        if tid not in books:
            from repro.online import SnapshotBook
            from repro.serve import LinearScorer
            books[tid] = SnapshotBook(np.zeros_like(np.asarray(res.w)))
            scorers[tid] = LinearScorer(res.w, loss=args.loss)
        snap = books[tid].publish(res.w, res.alpha, trained_seq=res.iters)
        scorers[tid].update_weights(res.w, snap.version)

    sched = FleetScheduler(
        P=P, Q=Q, solver=args.solver, engine=args.engine,
        local_backend=args.backend, block_format=args.block_format,
        cfg=cfg, tol=args.tol, check_every=args.check_every,
        max_tenants=args.max_tenants, on_result=on_result,
        tracer=plane.tracer_or(tracer), registry=registry,
        monitor=plane.monitor)

    print(f"[fleet] {args.solver} engine={args.engine} "
          f"backend={args.backend} block_format={args.block_format} "
          f"grid={P}x{Q} tenants={args.tenants} loss={args.loss} "
          f"rounds={args.rounds}")

    tenants = {}
    t0 = time.perf_counter()
    with plane.crash_guard():
        for r in range(args.rounds):
            for p in problems:
                sched.submit(p)
            buckets = len(sched.buckets())
            results = sched.run()
            _report_round(r, problems, results, tenants, books, args)
    total_s = time.perf_counter() - t0

    solves = args.tenants * args.rounds
    summary = {
        "solver": args.solver, "engine": args.engine,
        "local_backend": args.backend, "block_format": args.block_format,
        "P": P, "Q": Q, "loss": args.loss, "tenants": args.tenants,
        "rounds": args.rounds, "buckets": buckets,
        "total_s": total_s, "solves_per_s": solves / total_s,
        "results": list(tenants.values()),
    }
    if registry is not None:
        summary["metrics"] = registry.snapshot()
    if plane.active:
        summary["obs"] = plane.finalize()
    if tracer is not None:
        tracer.write_chrome_trace(args.trace)
        base, _ = os.path.splitext(args.trace)
        tracer.write_jsonl(base + ".jsonl")
        print(f"[fleet] trace: {len(tracer.events)} events -> "
              f"{args.trace} (+ {base + '.jsonl'})")
    print(json.dumps(summary, indent=1))
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(summary, fh, indent=1)
    return summary


if __name__ == "__main__":
    main()
