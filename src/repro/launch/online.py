"""CLI over the online learning service (``repro.online``).

Drives a synthetic observation stream through the full request
lifecycle -- admission queue, grid store, warm-started gated solver
passes, snapshot publish, live scoring -- and prints a per-round
staleness/throughput report plus a final JSON summary:

  PYTHONPATH=src python -m repro.launch.online \\
      --m 64 --capacity 512 --mesh 2x2 --rounds 20 --batch 32

  # production shard_map engine (one device per grid cell):
  PYTHONPATH=src python -m repro.launch.online \\
      --mesh 4x2 --engine shard_map --backend pallas \\
      --force-host-devices 8

  # persist every published version and recover from the newest one:
  PYTHONPATH=src python -m repro.launch.online --ckpt-dir /tmp/online_ck

  # telemetry: Chrome-trace spans of ingest/update/swap/score plus the
  # staleness gauge / update histograms in the summary JSON
  PYTHONPATH=src python -m repro.launch.online --trace /tmp/online.json \\
      --metrics
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse_mesh(s: str):
    try:
        p, q = s.lower().split("x")
        return int(p), int(q)
    except ValueError:
        raise argparse.ArgumentTypeError(f"--mesh expects PxQ, got {s!r}")


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.online",
        description="Streaming doubly distributed solver service CLI")
    ap.add_argument("--solver", default="d3ca",
                    help="row-gate-capable solver (d3ca)")
    ap.add_argument("--engine", default="simulated",
                    choices=["simulated", "shard_map", "sync", "async",
                             "overlap"])
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--block-format", default="dense",
                    choices=["dense", "sparse"])
    ap.add_argument("--staleness", type=int, default=0, metavar="TAU")
    ap.add_argument("--compression", default=None, metavar="SPEC")
    ap.add_argument("--topology", default=None, metavar="SPEC")
    ap.add_argument("--mesh", type=_parse_mesh, default=(2, 2),
                    metavar="PxQ", help="grid shape, e.g. 2x2")
    ap.add_argument("--m", type=int, default=64, help="feature dimension")
    ap.add_argument("--capacity", type=int, default=512,
                    help="observation window (GridStore rows)")
    ap.add_argument("--loss", default="hinge",
                    choices=["hinge", "squared", "logistic"])
    ap.add_argument("--lam", type=float, default=1e-2)
    ap.add_argument("--passes", type=int, default=2,
                    help="warm-started outer iterations per drained batch")
    ap.add_argument("--rounds", type=int, default=20,
                    help="stream rounds (each: submit, update, score)")
    ap.add_argument("--batch", type=int, default=32,
                    help="observations per stream round")
    ap.add_argument("--score-batch", type=int, default=128,
                    help="scoring requests per round")
    ap.add_argument("--queue-capacity", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="persist published versions here (and recover "
                         "from the newest before streaming)")
    ap.add_argument("--force-host-devices", type=int, default=None,
                    help="fake N CPU devices (before jax init; needed "
                         "for --engine shard_map on a laptop)")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write Chrome-trace JSON of the "
                         "ingest/update/swap/score spans")
    ap.add_argument("--metrics", action="store_true",
                    help="include the service's metrics snapshot "
                         "(staleness gauge, update/swap histograms, "
                         "throughput counters) in the summary JSON")
    ap.add_argument("--max-staleness", type=float, default=60.0,
                    help="--health: CRIT when the served snapshot is "
                         "older than this many seconds")
    ap.add_argument("--max-lag", type=float, default=10_000,
                    help="--health: CRIT when the served model trails "
                         "the stream by more than this many admitted "
                         "observations")
    from .obs import add_obs_flags
    add_obs_flags(ap)
    return ap


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    ap = build_parser()
    args = ap.parse_args(argv)

    if args.force_host_devices:
        if "jax" in sys.modules:
            print("warning: jax already initialized; "
                  "--force-host-devices has no effect", file=sys.stderr)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.force_host_devices}").strip()

    import numpy as np

    from repro.core import get_solver, objective
    from repro.launch.mesh import make_grid_mesh
    from repro.online import OnlineConfig, OnlineSolverService

    P, Q = args.mesh
    mesh = None if args.engine == "simulated" else make_grid_mesh(P, Q)
    manager = None
    if args.ckpt_dir:
        from repro.checkpoint.manager import CheckpointManager
        manager = CheckpointManager(args.ckpt_dir, keep_n=3)
    tracer = registry = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics:
        from repro.obs import Registry
        registry = Registry()
    from .obs import build_plane
    plane_rules = None
    if args.health:
        from repro.obs import online_rules
        plane_rules = online_rules(max_staleness_s=args.max_staleness,
                                   max_lag=args.max_lag)
    plane = build_plane(args, rules=plane_rules, registry=registry,
                        meta={"cli": "online", "solver": args.solver,
                              "engine": args.engine})
    registry = plane.registry if plane.active else registry

    cls = get_solver(args.solver)
    cfg = cls.config_cls(lam=args.lam)
    config = OnlineConfig(
        m=args.m, capacity=args.capacity, P=P, Q=Q, loss=args.loss,
        solver=args.solver, engine=args.engine,
        local_backend=args.backend, block_format=args.block_format,
        staleness=args.staleness, compression=args.compression,
        topology=args.topology, solver_cfg=cfg, passes=args.passes,
        queue_capacity=args.queue_capacity)
    svc = OnlineSolverService(config, mesh=mesh, manager=manager,
                              tracer=plane.tracer_or(tracer),
                              registry=registry, monitor=plane.monitor)
    recovered = svc.recover()
    if recovered is not None:
        print(f"[online] recovered snapshot version {recovered} from "
              f"{args.ckpt_dir}")

    rng = np.random.default_rng(args.seed)
    w_star = np.linspace(-1.0, 1.0, args.m).astype(np.float32)

    def stream(b):
        X = rng.normal(size=(b, args.m)).astype(np.float32)
        y = np.sign(X @ w_star + 0.1 * rng.normal(size=b))
        y = np.where(y == 0, 1.0, y).astype(np.float32)
        return X, y

    print(f"[online] {args.solver} engine={args.engine} "
          f"backend={args.backend} grid={P}x{Q} m={args.m} "
          f"capacity={svc.store.capacity} passes={args.passes} "
          f"loss={args.loss} lam={args.lam}")
    with plane.crash_guard():
        for r in range(args.rounds):
            svc.submit(*stream(args.batch))
            version = svc.run_pending()
            Xs, ys = stream(args.score_batch)
            acc = float(np.mean(svc.predict(Xs) * ys > 0)) \
                if args.loss != "logistic" else float("nan")
            mask = svc.store.filled_mask > 0
            f = float(objective(args.loss, svc.store.X[mask],
                                svc.store.y[mask],
                                svc.book.current().w, args.lam))
            print(f"  round={r:3d} version={version} "
                  f"filled={svc.store.filled}/{svc.store.capacity} "
                  f"f={f:.5f} acc={acc:.3f} lag={svc.version_lag} "
                  f"staleness={svc.staleness_s * 1e3:.1f}ms")
    if manager is not None:
        svc.book.flush()

    summary = dict(svc.stats())
    summary.update(solver=args.solver, engine=args.engine,
                   backend=args.backend, block_format=args.block_format,
                   P=P, Q=Q, m=args.m, loss=args.loss, lam=args.lam,
                   passes=args.passes, rounds=args.rounds,
                   batch=args.batch, objective=f)
    if registry is not None:
        summary["metrics"] = registry.snapshot()
    if plane.active:
        summary["obs"] = plane.finalize()
    if tracer is not None:
        tracer.write_chrome_trace(args.trace)
        base, _ = os.path.splitext(args.trace)
        tracer.write_jsonl(base + ".jsonl")
        print(f"[online] trace: {len(tracer.events)} events -> "
              f"{args.trace} (+ {base + '.jsonl'})")
    print(json.dumps(summary, indent=1))
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(summary, fh, indent=1)
    return summary


if __name__ == "__main__":
    main()
