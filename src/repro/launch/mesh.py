"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (required by the dry-run's forced host-device count).
"""
from __future__ import annotations

import functools

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


@functools.lru_cache(maxsize=None)
def make_grid_mesh(P: int, Q: int):
    """The paper's P x Q doubly distributed grid.

    Memoized: a Mesh is immutable and building one re-enumerates
    devices, so repeated solves (the online update loop, the fleet)
    reuse the same object -- which also keeps jit caches warm, since
    mesh identity participates in shard_map cache keys.
    """
    return jax.make_mesh((P, Q), ("data", "model"))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available (jax >= 0.6), else the
    legacy ``with mesh:`` context manager (Mesh.__enter__)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh
