import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init).  For every cell we:

  1. build ShapeDtypeStruct stand-ins (weak-type correct, sharded, no
     allocation) for params / optimizer state / batch / cache,
  2. ``jax.jit(step).lower(...)`` -> ``.compile()`` under the production
     mesh -- sharding mismatches, unsupported collectives and
     compile-time OOMs all surface here,
  3. record cost_analysis / memory_analysis / per-collective bytes into
     experiments/dryrun/*.json (consumed by benchmarks/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time

import jax

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.launch.steps import input_specs
from repro.models.config import LM_SHAPES
from repro.roofline.hlo import collective_bytes_from_hlo

OUT_DIR = os.environ.get("REPRO_DRYRUN_DIR",
                         os.path.join(os.path.dirname(__file__),
                                      "../../../experiments/dryrun"))


def _cost_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: 0.4.x
    returns a list with one dict per computation, newer jax a dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def cell_skip_reason(cfg, shape):
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full softmax attention is O(S) memory per decoded token at "
                "S=524288; skipped per assignment rules (DESIGN.md §5)")
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool, save: bool = True):
    cfg = get_config(arch)
    shape = next(s for s in LM_SHAPES if s.name == shape_name)
    skip = cell_skip_reason(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
              "kind": shape.kind}
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        _save(result, arch, shape_name, mesh_name, save)
        print(f"[dryrun] {cfg.name} x {shape.name} x {mesh_name}: SKIP ({skip})")
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh_context(mesh):
        cell = input_specs(cfg, shape, mesh)
        if cell.kind == "train":
            args = (cell.params, cell.opt, cell.batch)
            jitted = jax.jit(cell.fn, donate_argnums=(0, 1))
        elif cell.kind == "prefill":
            args = (cell.params, cell.batch)
            jitted = jax.jit(cell.fn)
        else:
            args = (cell.params, cell.cache, cell.batch)
            jitted = jax.jit(cell.fn, donate_argnums=(1,))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        # Gradient accumulation runs under a lax.scan whose body
        # cost_analysis counts ONCE (one microbatch).  For roofline
        # numbers comparable across accum settings, additionally lower an
        # accum_steps=1 variant and take FLOPs / bytes / wire bytes from
        # it; the memory-fit proof stays with the accumulated compile.
        cost_compiled = compiled
        if cell.kind == "train" and cfg.train_accum > 1:
            import dataclasses as _dc
            cfg1 = _dc.replace(cfg, train_accum=1, loss_chunk=None)
            cell1 = input_specs(cfg1, shape, mesh)
            cost_compiled = jax.jit(
                cell1.fn, donate_argnums=(0, 1)).lower(
                cell1.params, cell1.opt, cell1.batch).compile()
            result["accum_steps"] = cfg.train_accum

    cost = _cost_dict(cost_compiled)
    result["status"] = "ok"
    result["lower_s"] = round(t_lower, 2)
    result["compile_s"] = round(t_compile, 2)
    result["flops"] = float(cost.get("flops", 0.0))
    result["bytes_accessed"] = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:   # CPU backend may not implement it
        result["memory"] = {"error": str(e)[:200]}
    try:
        hlo = cost_compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    result["collectives"] = collective_bytes_from_hlo(hlo)
    _save(result, arch, shape_name, mesh_name, save)
    print(f"[dryrun] {cfg.name} x {shape.name} x {mesh_name}: OK "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
          f"GFLOP {result['flops']/1e9:.1f}, "
          f"coll GB {result['collectives']['total_bytes']/1e9:.3f})")
    return result


def _save(result, arch, shape_name, mesh_name, save):
    if not save:
        return
    os.makedirs(OUT_DIR, exist_ok=True)
    fn = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(fn, "w") as fh:
        json.dump(result, fh, indent=1)


def run_calibration(arch: str, shape_name: str, save: bool = True):
    """Lower two small UNROLLED variants (1 and 2 pattern-periods, full
    attention, single-chunk MoE) to measure exact per-period HLO costs.

    cost_analysis counts a lax.scan (while loop) body ONCE regardless of
    trip count, so the full-model numbers undercount the layer stack; the
    difference B - A of the unrolled variants is the exact per-period cost
    (compute, bytes, wire bytes), which benchmarks/roofline.py uses to
    extrapolate: total = full + (n_periods - 1) * per_period.
    """
    import dataclasses
    cfg = get_config(arch)
    shape = next(s for s in LM_SHAPES if s.name == shape_name)
    if cell_skip_reason(cfg, shape):
        return None
    k = len(cfg.pattern)
    mesh = make_production_mesh(multi_pod=False)
    out = {"arch": cfg.name, "shape": shape.name, "variants": {}}
    for label, layers in (("A", k), ("B", 2 * k)):
        # MoE keeps its production chunk size: moe_ffn unrolls the chunk
        # loop in Python under cfg.unroll so every chunk is counted
        # (inflating the chunk would make dispatch cost O(S^2) -- wrong).
        ccfg = dataclasses.replace(
            cfg, n_layers=layers, unroll=True, attn_impl="full",
            train_accum=1, loss_chunk=None)
        t0 = time.time()
        with mesh_context(mesh):
            cell = input_specs(ccfg, shape, mesh)
            if cell.kind == "train":
                args = (cell.params, cell.opt, cell.batch)
            elif cell.kind == "prefill":
                args = (cell.params, cell.batch)
            else:
                args = (cell.params, cell.cache, cell.batch)
            lowered = jax.jit(cell.fn).lower(*args)
            compiled = lowered.compile()
        cost = _cost_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        out["variants"][label] = {
            "layers": layers,
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives": collective_bytes_from_hlo(hlo),
            "compile_s": round(time.time() - t0, 2),
        }
        print(f"[calib] {arch} x {shape_name} {label}({layers}L): "
              f"GFLOP {out['variants'][label]['flops']/1e9:.2f}")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        with open(os.path.join(
                OUT_DIR, f"{arch}__{shape_name}__calib.json"), "w") as fh:
            json.dump(out, fh, indent=1)
    return out


def run_paper_cell(algo: str = "d3ca", multi_pod: bool = False,
                   save: bool = True, block_n: int = 40960,
                   block_m: int = 5120, inner_steps: int = None,
                   local_backend: str = "ref"):
    """Dry-run the paper's own doubly distributed workload (hinge SVM) at
    production mesh scale: one (block_n x block_m) block per chip, i.e.
    the paper's weak-scaling cell (40k x 5k) per device.

    The step builders come from the unified solver registry
    (``get_solver(algo).make_step``), so the dry-run lowers exactly the
    shard_map step the ``Solver`` API runs, under either local backend.

    The inner solver is a sequential lax.scan whose body cost_analysis
    counts once; we therefore also lower 1-step and 2-step variants and
    record the per-inner-step delta so the roofline can extrapolate
    total = full + (steps - 1) * (B - A), exactly like the layer-scan
    calibration for the LM archs.
    """
    import numpy as np
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.core import (D3CAConfig, RADiSAConfig, get_loss, get_solver)
    import jax.numpy as jnp

    mesh = make_production_mesh(multi_pod=multi_pod)
    daxes = ("pod", "data") if multi_pod else ("data",)
    Pn = 1
    for a in daxes:
        Pn *= mesh.shape[a]
    Qn = mesh.shape["model"]
    n, m = Pn * block_n, Qn * block_m
    mesh_name = "2x16x16" if multi_pod else "16x16"
    inner = inner_steps or block_n     # one local epoch, as the paper

    def sds(shape, spec):
        return jax.ShapeDtypeStruct(shape, jnp.float32,
                                    sharding=NamedSharding(mesh, spec))

    loss = get_loss("hinge")
    make_step = get_solver(algo).make_step
    x = sds((n, m), P(daxes, "model"))
    y, maskv = sds((n,), P(daxes)), sds((n,), P(daxes))
    key0 = jax.random.PRNGKey(0)
    t_arg = np.int32(1)

    def lower_one(steps):
        if algo == "d3ca":
            step = make_step(
                loss, mesh, D3CAConfig(lam=1e-2, local_steps=steps),
                n=n, n_p=block_n, data_axis=daxes,
                local_backend=local_backend)
            args = (t_arg, key0, x, y, maskv, sds((n,), P(daxes)),
                    sds((m,), P("model")))
        else:
            step = make_step(
                loss, mesh, RADiSAConfig(lam=1e-3, L=steps),
                n=n, n_p=block_n, m_q=block_m, data_axis=daxes,
                local_backend=local_backend)
            args = (t_arg, key0, x, y, maskv, sds((m,), P("model")))
        t0 = time.time()
        lowered = step.lower(*args)
        compiled = lowered.compile()
        cost = _cost_dict(compiled)
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        out = {
            "steps": int(steps),
            "compile_s": round(time.time() - t0, 2),
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives": collective_bytes_from_hlo(hlo),
        }
        try:
            mem = compiled.memory_analysis()
            out["memory"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:
            out["memory"] = {"error": str(e)[:200]}
        return out

    result = {"arch": f"paper-svm-{algo}", "shape": f"{block_n}x{block_m}",
              "mesh": mesh_name, "kind": "paper", "status": "ok",
              "P": Pn, "Q": Qn, "inner_steps": inner,
              "local_backend": local_backend,
              "full": lower_one(inner),
              "calib_A": lower_one(1), "calib_B": lower_one(2)}
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = "" if local_backend == "ref" else f"__{local_backend}"
        fn = os.path.join(
            OUT_DIR, f"paper_svm_{algo}__{mesh_name}{suffix}.json")
        with open(fn, "w") as fh:
            json.dump(result, fh, indent=1)
    f = result["full"]
    print(f"[dryrun] paper-svm-{algo} x {mesh_name}: OK "
          f"(GFLOP {f['flops']/1e9:.2f}, "
          f"coll GB {f['collectives']['total_bytes']/1e9:.3f}, "
          f"temp G {f['memory'].get('temp_size_in_bytes', 0)/2**30:.2f})")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--calib", action="store_true",
                    help="run the per-period cost calibration instead")
    ap.add_argument("--paper", choices=["d3ca", "radisa"], default=None,
                    help="dry-run the paper's SVM workload instead")
    ap.add_argument("--backend", choices=["ref", "pallas"], default="ref",
                    help="cell-local solver backend for --paper")
    args = ap.parse_args()

    if args.paper:
        run_paper_cell(args.paper, multi_pod=args.multi_pod,
                       local_backend=args.backend)
        return

    if args.all:
        ok = True
        for arch in ARCHS:
            for shape in LM_SHAPES:
                try:
                    if args.calib:
                        run_calibration(arch, shape.name)
                    else:
                        run_cell(arch, shape.name, args.multi_pod)
                except Exception as e:
                    ok = False
                    print(f"[dryrun] {arch} x {shape.name}: FAIL {e!r}",
                          file=sys.stderr)
        sys.exit(0 if ok else 1)

    if args.calib:
        run_calibration(args.arch, args.shape or "train_4k")
    else:
        run_cell(args.arch, args.shape or "train_4k", args.multi_pod)


if __name__ == "__main__":
    main()
