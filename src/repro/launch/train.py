"""End-to-end LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (CPU here, pod in production: the same
code path; only the mesh shape changes).  Demonstrates the full stack:
deterministic sharded data pipeline -> jitted train step with doubly
distributed sharding -> AdamW -> fault-tolerant trainer (async ckpt,
NaN rollback, preemption save, straggler log).
"""
from __future__ import annotations

import argparse
import logging

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.tokens import synthetic_token_batch
from ..models import Transformer, reduced
from ..optim import AdamWConfig, adamw_init, warmup_cosine
from ..runtime import Trainer, TrainerConfig
from ..sharding.rules import batch_axes
from .mesh import make_mesh, mesh_context
from .steps import make_train_step, param_shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4,2' for a 4x2 (data, model) mesh")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh(shape, ("data", "model")[: len(shape)])
    else:
        mesh = make_mesh((jax.device_count(), 1), ("data", "model"))

    model = Transformer(cfg, mesh=mesh)
    opt_cfg = AdamWConfig(lr=warmup_cosine(args.lr, 20, args.steps))

    with mesh_context(mesh):
        pstructs, _, pspecs = param_shardings(model, mesh)
        params = jax.jit(
            lambda k: model.init(k)[0],
            out_shardings=jax.tree.map(lambda s: s.sharding, pstructs),
        )(jax.random.PRNGKey(0))
        opt_state = jax.jit(adamw_init)(params)
        step_fn = jax.jit(make_train_step(model, opt_cfg),
                          donate_argnums=(0, 1))

        def make_batch(step):
            b = synthetic_token_batch(step, batch=args.batch, seq=args.seq,
                                      vocab=cfg.vocab)
            if cfg.embed_input != "tokens":
                rng = np.random.default_rng(step)
                b = {"embeds": rng.normal(size=(args.batch, args.seq,
                                                cfg.d_model)).astype("float32"),
                     "labels": b["labels"]}
            if cfg.encoder_len:
                rng = np.random.default_rng(10_000 + step)
                b["encoder"] = rng.normal(
                    size=(args.batch, cfg.encoder_len, cfg.d_model)
                ).astype("float32")
            return b

        trainer = Trainer(
            TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
            step_fn, make_batch, params, opt_state)
        if args.resume:
            print("resumed at step", trainer.restore())
        history = trainer.run(args.steps)

    losses = [h["loss"] for h in history]
    print(f"steps={len(history)} first_loss={losses[0]:.4f} "
          f"last_loss={losses[-1]:.4f} stragglers={trainer.stragglers[:5]}")
    return history


if __name__ == "__main__":
    main()
