"""Jitted step builders + ShapeDtypeStruct input specs for every
(architecture x input shape) cell.

Everything here works identically with real arrays (examples, smoke tests)
and with ShapeDtypeStruct stand-ins (the 512-device dry-run lowers
``train_step`` / ``serve_step`` without allocating anything).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig
from ..models.transformer import Transformer
from ..optim import AdamWConfig, adamw_init, adamw_update
from ..sharding.rules import batch_axes, logical_to_spec, spec_tree


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def param_shardings(model: Transformer, mesh, key=None):
    """(param ShapeDtypeStructs with shardings, logical tree, spec tree)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    captured = {}

    def only_params(k):
        p, logical_ = model.init(k)
        captured["logical"] = logical_  # static py structure; side-channel out
        return p

    shapes = jax.eval_shape(only_params, key)
    logical = captured["logical"]
    specs = spec_tree(logical, shapes, mesh)
    structs = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=_named(mesh, sp)),
        shapes, specs)
    return structs, logical, specs


def opt_shardings(param_structs, mesh, param_specs):
    """AdamW state shards exactly like the params."""
    shapes = jax.eval_shape(adamw_init, param_structs)
    mu_spec = param_specs
    count_spec = P()

    def build(path_tree, spec):
        return jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                               sharding=_named(mesh, sp)),
            path_tree, spec)

    return {
        "mu": build(shapes["mu"], mu_spec),
        "nu": build(shapes["nu"], mu_spec),
        "count": jax.ShapeDtypeStruct((), jnp.int32,
                                      sharding=_named(mesh, count_spec)),
    }


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict[str, Any]:
    """ShapeDtypeStructs for one input batch of the given shape."""
    b = batch_axes(mesh)
    B = shape.batch
    S = 1 if shape.kind == "decode" else shape.seq
    bspec = b if B % _axsize(mesh, b) == 0 else ()
    specs = {}
    if cfg.embed_input == "tokens":
        specs["tokens"] = jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=_named(mesh, P(bspec)))
    else:
        specs["embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), cfg.cdtype,
            sharding=_named(mesh, P(bspec, None, None)))
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=_named(mesh, P(bspec)))
    if cfg.encoder_len:
        specs["encoder"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_len, cfg.d_model), cfg.cdtype,
            sharding=_named(mesh, P(bspec, None, None)))
    return specs


def _axsize(mesh, axes):
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def _cache_logical(model: Transformer, mesh):
    """Logical axes for decode-cache leaves.

    KV caches shard their KV-head dim over "model" when it divides
    (attention stays head-local); otherwise they shard the cache LENGTH
    (sequence-parallel / flash-decoding style) -- sharding the head_dim
    instead (the old fallback) made GSPMD insert involuntary full
    rematerializations of the 32k cache per layer per token.
    """
    kv_div = ("model" in mesh.axis_names
              and model.cfg.n_kv % mesh.shape["model"] == 0)
    kv = ((None, "batch", None, "kv_heads", None) if kv_div
          else (None, "batch", "kv_len", None, None))
    return {
        "k": kv,
        "v": kv,
        "k_scale": kv[:-1],
        "v_scale": kv[:-1],
        "state": (None, "batch", "heads", None, None),
        "x_tm": (None, "batch", "model_dim"),
        "x_cm": (None, "batch", "model_dim"),
        "h": (None, "batch", "ff"),
        "pos": (),
    }


def cache_specs(model: Transformer, shape: ShapeConfig, mesh):
    """ShapeDtypeStructs (with shardings) for the decode cache."""
    _CACHE_LOGICAL = _cache_logical(model, mesh)
    shapes = jax.eval_shape(
        partial(model.make_cache, shape.batch, shape.seq))

    def leaf_spec(path, leaf):
        name = None
        for k in path:
            key = str(getattr(k, "key", getattr(k, "idx", "")))
            if key in _CACHE_LOGICAL:
                name = key
        logical = _CACHE_LOGICAL.get(name, ())
        logical = logical[: len(leaf.shape)] if logical else (
            (None,) * len(leaf.shape))
        # pad logical to rank
        logical = tuple(logical) + (None,) * (len(leaf.shape) - len(logical))
        spec = logical_to_spec(leaf.shape, logical, mesh)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=_named(mesh, spec))

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    return jax.tree.unflatten(treedef,
                              [leaf_spec(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def _largest_divisor_leq(n: int, k: int) -> int:
    """Largest divisor of ``n`` that is <= ``k`` (>=1)."""
    k = max(1, min(n, k))
    while n % k:
        k -= 1
    return k


def make_train_step(model: Transformer, opt_cfg: AdamWConfig,
                    accum_steps: Optional[int] = None):
    """Train step with gradient accumulation.

    The global batch is split along its leading axis into ``accum_steps``
    microbatches processed sequentially under a ``lax.scan``: the scan
    body's temporaries (saved activations for one microbatch's backward)
    are reused across iterations, so per-device live activations shrink by
    the accumulation factor -- this is what makes the 4k x 256 train
    shapes fit a 16 GB v5e chip.  (An unrolled loop with
    ``lax.optimization_barrier`` does NOT work: the XLA CPU pipeline
    elides the barriers and schedules all forwards first, keeping every
    microbatch's saved activations live -- verified via buffer-assignment
    dumps, see EXPERIMENTS.md §Perf.)

    Note for cost accounting: ``cost_analysis`` counts a scan body once,
    so this step's FLOPs/bytes reflect ONE microbatch; the dry-run
    additionally lowers an ``accum_steps=1`` variant for roofline numbers.
    """
    def train_step(params, opt_state, batch):
        B = jax.tree.leaves(batch)[0].shape[0]
        req = model.cfg.train_accum if accum_steps is None else accum_steps
        acc = _largest_divisor_leq(B, req)
        mb = B // acc

        def loss_grads(p, sub):
            return jax.value_and_grad(model.train_loss)(p, sub)

        if acc == 1:
            loss, grads = loss_grads(params, batch)
        else:
            # Reshape (B, ...) -> (acc, mb, ...) STATICALLY and scan over
            # xs.  Slicing the batch-sharded dim with a traced start index
            # instead would make GSPMD all-gather the whole batch to every
            # device (8.6 GB for the VLM encoder states) because it cannot
            # prove a dynamic slice stays within one shard; the scan dim
            # of the reshaped xs is unsharded, so per-iteration slicing is
            # local.
            xs = jax.tree.map(
                lambda a: a.reshape((acc, mb) + a.shape[1:]), batch)

            def body(carry, sub):
                loss_acc, g_acc = carry
                li, gi = loss_grads(params, sub)
                return (loss_acc + li,
                        jax.tree.map(jnp.add, g_acc, gi)), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), xs)
            inv = 1.0 / acc
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)

        params, opt_state, gnorm = adamw_update(opt_cfg, grads, opt_state,
                                                params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(model: Transformer, cache_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len)

    return prefill_step


def make_decode_step(model: Transformer):
    def serve_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    return serve_step


# ---------------------------------------------------------------------------
# full per-cell spec assembly (used by dryrun + benchmarks)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellSpecs:
    params: Any
    opt: Optional[Any]
    batch: Any
    cache: Optional[Any]
    fn: Any           # callable to jit+lower; args per `kind`
    kind: str


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                opt_cfg: Optional[AdamWConfig] = None) -> CellSpecs:
    model = Transformer(cfg, mesh=mesh)
    pstructs, _, pspecs = param_shardings(model, mesh)
    batch = batch_specs(cfg, shape, mesh)
    if shape.kind == "train":
        opt_cfg = opt_cfg or AdamWConfig()
        ostructs = opt_shardings(pstructs, mesh, pspecs)
        return CellSpecs(pstructs, ostructs, batch, None,
                         make_train_step(model, opt_cfg), "train")
    if shape.kind == "prefill":
        return CellSpecs(pstructs, None, batch, None,
                         make_prefill_step(model, shape.seq), "prefill")
    cache = cache_specs(model, shape, mesh)
    return CellSpecs(pstructs, None, batch, cache,
                     make_decode_step(model), "decode")
