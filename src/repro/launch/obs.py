"""Shared observability-plane flags for the launch CLIs.

Every long-running entry point (``optimize``, ``online``, ``serve``,
``fleet``) exposes the same three switches:

  ``--listen HOST:PORT``      start the stdlib HTTP endpoint
                              (``/metrics`` Prometheus, ``/healthz``,
                              ``/varz``); ``:0`` picks a free port and
                              prints it
  ``--health``                evaluate the service's default
                              :mod:`repro.obs.health` rule set while
                              the job runs
  ``--flight-recorder OUT``   keep a bounded ring-buffer trace
                              (``--flight-capacity`` events) and write
                              a postmortem bundle to OUT on crash, on
                              any health CRIT transition, and on clean
                              exit (reason ``exit``)

:func:`add_obs_flags` installs them on an argparse parser;
:func:`build_plane` turns the parsed args into an :class:`ObsPlane`
holding the wired registry / recorder / monitor / server, plus the
teardown (:meth:`ObsPlane.finalize`) and crash capture
(:meth:`ObsPlane.crash_guard`) the CLI main loops wrap themselves in.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import sys
from typing import Optional


def add_obs_flags(ap):
    """Install ``--listen`` / ``--health`` / ``--flight-recorder`` /
    ``--flight-capacity`` on ``ap``; returns ``ap``."""
    g = ap.add_argument_group("observability plane")
    g.add_argument("--listen", default=None, metavar="HOST:PORT",
                   help="serve /metrics (Prometheus text), /healthz "
                        "(503 on CRIT), and /varz on a background "
                        "thread; ':0' and 'HOST:0' bind a free port "
                        "(printed on start)")
    g.add_argument("--health", action="store_true",
                   help="evaluate this service's default health rules "
                        "(divergence, staleness, queue shed, ...) while "
                        "the job runs; verdicts land in the registry "
                        "and on /healthz")
    g.add_argument("--flight-recorder", default=None, metavar="OUT.json",
                   dest="flight_recorder",
                   help="keep a bounded ring-buffer trace and write a "
                        "postmortem bundle (trace tail + metrics "
                        "snapshot + provenance) to OUT.json on crash, "
                        "health CRIT, or clean exit")
    g.add_argument("--flight-capacity", type=int, default=None,
                   metavar="N", dest="flight_capacity",
                   help="flight-recorder ring capacity in events "
                        "(default 4096)")
    return ap


def parse_listen(spec: str):
    """``'HOST:PORT'`` / ``':PORT'`` / ``'PORT'`` -> (host, port)."""
    host, _, port = spec.rpartition(":")
    host = host or "127.0.0.1"
    try:
        return host, int(port)
    except ValueError:
        raise ValueError(f"--listen expects HOST:PORT, got {spec!r}")


@dataclasses.dataclass
class ObsPlane:
    """The wired observability plane of one CLI invocation.

    Any attribute may be None when its flag was off; ``registry`` is
    non-None whenever at least one obs flag was given (the caller may
    also have forced it with its own ``--metrics`` flag)."""
    registry: Optional[object] = None
    recorder: Optional[object] = None
    monitor: Optional[object] = None
    server: Optional[object] = None
    dump_path: Optional[str] = None

    @property
    def active(self) -> bool:
        return self.registry is not None

    def tracer_or(self, tracer):
        """The tracer the solve should run under: an explicit
        ``--trace`` Tracer wins; otherwise the flight recorder (which
        shares the span API); otherwise None."""
        return tracer if tracer is not None else self.recorder

    def crash_guard(self):
        """Context manager dumping the recorder bundle when the body
        raises (no-op without ``--flight-recorder``)."""
        if self.recorder is not None and self.dump_path is not None:
            return self.recorder.crash_guard(self.dump_path)
        return contextlib.nullcontext()

    def summary(self) -> dict:
        """JSON-able plane state for the CLI summary blob."""
        out = {}
        if self.server is not None:
            out["listen"] = self.server.url
        if self.monitor is not None:
            out["health"] = self.monitor.healthz(evaluate=True)
        if self.recorder is not None:
            out["flight_recorder"] = {
                "capacity": self.recorder.capacity,
                "retained": len(self.recorder.events),
                "dropped": self.recorder.dropped,
                "dumps": list(self.recorder.dumps),
            }
        return out

    def finalize(self, reason: str = "exit") -> dict:
        """Stop the endpoint and write the clean-exit bundle; returns
        :meth:`summary` (taken before teardown)."""
        out = self.summary()
        if self.server is not None:
            self.server.stop()
        if self.recorder is not None and self.dump_path is not None:
            try:
                self.recorder.dump(self.dump_path, reason=reason)
                out.setdefault("flight_recorder", {})["bundle"] = \
                    self.dump_path
            except Exception as e:
                print(f"[obs] flight-recorder dump failed: {e!r}",
                      file=sys.stderr)
        return out


def build_plane(args, *, rules=None, registry=None, meta=None,
                start_server: bool = True) -> ObsPlane:
    """Wire the plane from parsed CLI args.

    Args:
      args: argparse namespace carrying the :func:`add_obs_flags`
        attributes.
      rules: the service's default health-rule list for ``--health``
        (e.g. ``repro.obs.online_rules()``); required when --health is
        set.
      registry: an existing registry to attach to (the CLI's own
        ``--metrics`` one); a fresh one is created when any obs flag
        needs it.
      meta: provenance dict stamped into every recorder bundle.
      start_server: tests pass False to wire without binding.

    Returns an :class:`ObsPlane` (``.active`` False when no obs flag
    was given).
    """
    listen = getattr(args, "listen", None)
    health = getattr(args, "health", False)
    rec_path = getattr(args, "flight_recorder", None)
    capacity = getattr(args, "flight_capacity", None)
    if not (listen or health or rec_path):
        return ObsPlane(registry=registry)

    from repro.obs import FlightRecorder, HealthMonitor, ObsServer, Registry
    from repro.obs.recorder import DEFAULT_CAPACITY

    reg = registry if registry is not None else Registry()
    plane = ObsPlane(registry=reg, dump_path=rec_path)

    if rec_path:
        cap = capacity if capacity is not None else DEFAULT_CAPACITY
        plane.recorder = FlightRecorder(capacity=cap, registry=reg,
                                        meta=meta)
    if health:
        if rules is None:
            rules = []
        dump_dir = (os.path.dirname(os.path.abspath(rec_path))
                    if rec_path else None)
        plane.monitor = HealthMonitor(reg, rules,
                                      recorder=plane.recorder,
                                      dump_dir=dump_dir,
                                      min_interval_s=0.05)
    if listen:
        host, port = parse_listen(listen)
        plane.server = ObsServer(reg, monitor=plane.monitor,
                                 recorder=plane.recorder,
                                 host=host, port=port)
        if start_server:
            plane.server.start()
            print(f"[obs] serving /metrics /healthz /varz on "
                  f"{plane.server.url}")
    return plane
