"""CLI over the unified solver framework (``repro.core.solver``).

Run any of the paper's doubly distributed optimizers on a synthetic
dataset under any (engine, local_backend) pair:

  PYTHONPATH=src python -m repro.launch.optimize \\
      --solver d3ca --dataset dense --n 1600 --m 400 --mesh 4x2 \\
      --engine simulated --backend ref --loss hinge --lam 0.1 --iters 15

  # the production shard_map engine needs one device per grid cell;
  # --force-host-devices N fakes them on CPU (set before jax init):
  PYTHONPATH=src python -m repro.launch.optimize \\
      --solver radisa --mesh 4x2 --engine shard_map --backend pallas \\
      --force-host-devices 8

  # news20-scale sparse instances: --block-format sparse keeps every
  # block in the padded-ELL cell format (memory ~ nnz, never densified)
  PYTHONPATH=src python -m repro.launch.optimize \\
      --solver d3ca --dataset sparse --density 0.01 --n 20000 --m 50000 \\
      --block-format sparse

  # bounded-staleness reductions (Hogwild-style delayed psum): the async
  # engine applies every CommSchedule collective with delay tau;
  # --staleness 0 reproduces --engine shard_map bit for bit
  PYTHONPATH=src python -m repro.launch.optimize \\
      --solver d3ca --mesh 4x2 --engine async --staleness 2 \\
      --force-host-devices 8

  # compressed reductions: quantize every declared collective (or name
  # them individually) with error feedback; the summary reports exact
  # bytes-on-wire per outer step.  --compression identity is
  # bit-identical to no compression
  PYTHONPATH=src python -m repro.launch.optimize \\
      --solver d3ca --mesh 4x2 --engine shard_map \\
      --compression int8 --force-host-devices 8
  PYTHONPATH=src python -m repro.launch.optimize \\
      --solver radisa --compression "dw=topk:0.1,z=identity"

  # communication overlap: dispatch reductions asynchronously and hide
  # them behind tau steps of local solve (--staleness 0 is bit-identical
  # to shard_map); --topology splits the reductions into full-precision
  # intra-pod + codec-compressed cross-pod tiers; adaptive compression
  # switches codec stages as convergence flattens
  PYTHONPATH=src python -m repro.launch.optimize \\
      --solver d3ca --mesh 4x2 --engine overlap --staleness 2 \\
      --topology "pods=2:int8" --compression "adaptive" \\
      --force-host-devices 8

Prints one line per outer iteration (objective, duality gap when the
solver has a dual, relative optimality when --ref-epochs > 0) and a
final JSON summary.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse_mesh(s: str):
    try:
        p, q = s.lower().split("x")
        return int(p), int(q)
    except ValueError:
        raise argparse.ArgumentTypeError(f"--mesh expects PxQ, got {s!r}")


def build_parser():
    ap = argparse.ArgumentParser(
        prog="repro.launch.optimize",
        description="Unified doubly distributed solver CLI")
    ap.add_argument("--solver", default="d3ca",
                    help="d3ca | radisa | admm (see get_solver)")
    ap.add_argument("--engine", default="simulated",
                    choices=["simulated", "shard_map", "sync", "async",
                             "overlap"],
                    help="simulated = vmap grid on one device; shard_map "
                         "(alias: sync) = one block per device, synchronous "
                         "reductions; async = same mesh with "
                         "bounded-staleness reductions (--staleness); "
                         "overlap = async dispatch with donated in-flight "
                         "reduction slots so the local solve hides the "
                         "wire")
    ap.add_argument("--staleness", type=int, default=0, metavar="TAU",
                    help="async/overlap engines: apply every declared "
                         "reduction with delay TAU outer iterations "
                         "(0 = synchronous, identical to shard_map)")
    ap.add_argument("--compression", default=None, metavar="SPEC",
                    help="compress the declared collectives: a codec for "
                         "all of them ('int8', 'fp8', 'topk:0.1', "
                         "'identity'), per-collective "
                         "('w_contrib=int8,dalpha=identity'), or an "
                         "adaptive schedule "
                         "('adaptive[:topk:0.25->int8][@slope=..]') that "
                         "switches codec stages as convergence flattens; "
                         "codecs carry error feedback, and the summary "
                         "reports exact bytes-on-wire (default: no "
                         "compression)")
    ap.add_argument("--topology", default=None, metavar="SPEC",
                    help="hierarchical reductions, e.g. 'pods=2:int8': "
                         "full-precision psum within each pod, "
                         "codec-compressed across pods (default: flat)")
    ap.add_argument("--backend", default="ref", choices=["ref", "pallas"],
                    help="cell-local solver backend")
    ap.add_argument("--block-format", default="dense",
                    choices=["dense", "sparse"],
                    help="per-cell data layout: dense (n_p, m_q) tiles or "
                         "padded-ELL sparse cells (memory ~ nnz)")
    ap.add_argument("--mesh", type=_parse_mesh, default=(4, 2),
                    metavar="PxQ", help="grid shape, e.g. 4x2")
    ap.add_argument("--dataset", default="dense",
                    choices=["dense", "sparse", "libsvm"])
    ap.add_argument("--libsvm-path", default=None,
                    help="path for --dataset libsvm (streamed into CSR "
                         "when --block-format sparse)")
    ap.add_argument("--n", type=int, default=1600)
    ap.add_argument("--m", type=int, default=400)
    ap.add_argument("--problems", type=int, default=1, metavar="N",
                    help="fan out: solve N independent synthetic "
                         "instances (seeds seed..seed+N-1) as ONE "
                         "batched fleet solve sharing every collective "
                         "round and one compiled step (see "
                         "repro.launch.fleet for the multi-tenant "
                         "scheduler; engine simulated/shard_map only)")
    ap.add_argument("--density", type=float, default=0.05,
                    help="nonzero fraction for --dataset sparse")
    ap.add_argument("--loss", default="hinge",
                    choices=["hinge", "squared", "logistic"])
    ap.add_argument("--lam", type=float, default=1e-1)
    ap.add_argument("--iters", type=int, default=15)
    ap.add_argument("--tol", type=float, default=None,
                    help="early-stopping tolerance (see Solver.solve)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ref-epochs", type=int, default=100,
                    help="serial SDCA epochs for f*; 0 skips rel-opt")
    ap.add_argument("--force-host-devices", type=int, default=None,
                    help="fake N CPU devices (required before jax init "
                         "for --engine shard_map on a laptop)")
    ap.add_argument("--json-out", default=None,
                    help="write the summary JSON here as well")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="trace the solve and write Chrome-trace JSON "
                         "here (open in chrome://tracing or "
                         "ui.perfetto.dev); spans cover data prep, every "
                         "outer iteration, the cell-local solve and one "
                         "span per declared collective.  OUT.jsonl is "
                         "written next to it with the raw events")
    ap.add_argument("--metrics", action="store_true",
                    help="record solver metrics into a registry and "
                         "print its snapshot in the summary JSON")
    from .obs import add_obs_flags
    add_obs_flags(ap)
    return ap


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    ap = build_parser()
    args = ap.parse_args(argv)

    if args.staleness < 0:
        ap.error(f"--staleness {args.staleness} is negative; the reduction "
                 "delay tau must be >= 0 (0 = synchronous)")
    if args.staleness > 0 and args.engine not in ("async", "overlap"):
        ap.error(f"--staleness {args.staleness} only works with "
                 f"--engine async or --engine overlap; --engine "
                 f"{args.engine} applies every reduction synchronously "
                 "(pass --engine async/overlap, or drop --staleness)")

    if args.force_host_devices:
        if "jax" in sys.modules:
            print("warning: jax already initialized; "
                  "--force-host-devices has no effect", file=sys.stderr)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.force_host_devices}").strip()

    # jax (and everything that imports it) only after the device forcing
    from repro.core import get_solver, objective, serial_sdca
    from repro.data import (load_libsvm, load_libsvm_csr,
                            make_sparse_svm_csr, make_sparse_svm_data,
                            make_svm_data)

    P, Q = args.mesh
    sparse_fmt = args.block_format == "sparse"

    if args.problems > 1:
        return _fanout(ap, args, P, Q)

    if args.dataset == "dense":
        X, y = make_svm_data(args.n, args.m, seed=args.seed)
    elif args.dataset == "libsvm":
        if not args.libsvm_path:
            build_parser().error("--dataset libsvm needs --libsvm-path")
        loader = load_libsvm_csr if sparse_fmt else load_libsvm
        X, y = loader(args.libsvm_path)
    elif sparse_fmt:
        # CSR all the way down: the dense matrix is never materialized
        X, y = make_sparse_svm_csr(args.n, args.m, density=args.density,
                                   seed=args.seed)
    else:
        X, y = make_sparse_svm_data(args.n, args.m, density=args.density,
                                    seed=args.seed)

    f_star = None
    if args.ref_epochs > 0:
        n_, m_ = X.shape
        if hasattr(X, "toarray") and n_ * m_ > 20_000_000:
            print(f"[optimize] skipping f* reference: densifying "
                  f"{n_}x{m_} for serial SDCA would need "
                  f"{n_ * m_ * 4 / 1e9:.1f} GB (pass --ref-epochs 0 to "
                  "silence)", file=sys.stderr)
        else:
            X_ref = X.toarray() if hasattr(X, "toarray") else X
            w_ref, _ = serial_sdca(args.loss, X_ref, y, lam=args.lam,
                                   epochs=args.ref_epochs)
            f_star = float(objective(args.loss, X_ref, y, w_ref, args.lam))

    cls = get_solver(args.solver)
    solver = cls(engine=args.engine, local_backend=args.backend,
                 block_format=args.block_format, staleness=args.staleness,
                 compression=args.compression, topology=args.topology)
    cfg_kw = {"lam": args.lam, "outer_iters": args.iters}
    if args.solver == "admm":
        cfg_kw["rho"] = args.lam
    cfg = cls.config_cls(**cfg_kw)

    stale = (f" staleness={args.staleness}"
             if args.engine in ("async", "overlap") else "")
    comp = (f" compression={solver.compression_spec}"
            if solver.compression is not None else "")
    if solver.topology is not None:
        comp += f" topology={solver.topology_spec}"
    print(f"[optimize] {args.solver} engine={args.engine}{stale}{comp} "
          f"backend={args.backend} block_format={args.block_format} "
          f"grid={P}x{Q} "
          f"{args.dataset}({X.shape[0]}x{X.shape[1]}) loss={args.loss} "
          f"lam={args.lam}")
    tracer = registry = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics:
        from repro.obs import Registry
        registry = Registry()
    from .obs import build_plane
    plane_rules = None
    if args.health:
        from repro.obs import solver_rules
        plane_rules = solver_rules()
    plane = build_plane(args, rules=plane_rules, registry=registry,
                        meta={"cli": "optimize", "solver": args.solver,
                              "engine": args.engine})
    registry = plane.registry if plane.active else registry
    with plane.crash_guard():
        res = solver.solve(args.loss, X, y, P=P, Q=Q, cfg=cfg,
                           tol=args.tol, f_star=f_star,
                           tracer=plane.tracer_or(tracer),
                           registry=registry, monitor=plane.monitor)
    if res.comm_bytes is not None:
        acct = res.comm_bytes
        detail = ", ".join(
            f"{name}: {c['bytes_per_step']}B/step [{c['codec']}]"
            for name, c in acct["collectives"].items())
        print(f"[optimize] wire: {acct['bytes_per_step']} B/step "
              f"(uncompressed {acct['uncompressed_bytes_per_step']}) -- "
              f"{detail}")
    for h in res.history:
        line = (f"  t={h['iter']:3d}  {h['time_s']:7.2f}s  "
                f"f={h['objective']:.6f}")
        if "duality_gap" in h:
            line += f"  gap={h['duality_gap']:.3e}"
        if "rel_opt" in h:
            line += f"  rel_opt={h['rel_opt']:.4f}"
        print(line)

    phased = [h for h in res.history if "local_s" in h]
    if phased:
        tot = sum(h["step_s"] + h["host_s"] for h in phased)
        loc = sum(h["local_s"] for h in phased)
        com = sum(h["comm_s"] for h in phased)
        hst = sum(h["host_s"] for h in phased)
        line = (f"[optimize] phases: local {100 * loc / tot:.1f}% / "
                f"comm {100 * com / tot:.1f}% / host "
                f"{100 * hst / tot:.1f}% of {tot:.3f}s measured")
        if any("comm_exposed_s" in h for h in phased):
            exp = sum(h.get("comm_exposed_s", 0.0) for h in phased)
            hid = sum(h.get("comm_hidden_s", 0.0) for h in phased)
            line += (f" (comm exposed {100 * exp / tot:.1f}% / "
                     f"hidden {100 * hid / tot:.1f}%)")
        print(line)

    summary = {
        "solver": res.solver, "engine": res.engine,
        "staleness": res.staleness,
        "local_backend": res.local_backend,
        "block_format": res.block_format, "P": P, "Q": Q,
        "n": int(X.shape[0]), "m": int(X.shape[1]), "loss": args.loss,
        "lam": args.lam, "iters": res.iters, "converged": res.converged,
        "objective": res.history[-1]["objective"] if res.history else None,
        "rel_opt": res.history[-1].get("rel_opt") if res.history else None,
        "total_s": res.history[-1]["time_s"] if res.history else None,
        "compression": res.compression,
        "topology": res.topology,
        "comm_bytes_per_step": (res.comm_bytes or {}).get("bytes_per_step"),
        "comm_bytes_total": (res.history[-1].get("comm_bytes")
                             if res.history else None),
    }
    if registry is not None:
        summary["metrics"] = registry.snapshot()
    if plane.active:
        summary["obs"] = plane.finalize()
    if tracer is not None:
        tracer.write_chrome_trace(args.trace)
        base, _ = os.path.splitext(args.trace)
        tracer.write_jsonl(base + ".jsonl")
        print(f"[optimize] trace: {len(tracer.events)} events -> "
              f"{args.trace} (+ {base + '.jsonl'})")
    print(json.dumps(summary, indent=1))
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump({"summary": summary, "history": res.history}, fh,
                      indent=1)
    return summary


def _fanout(ap, args, P, Q):
    """--problems N: one batched fleet solve over N synthetic instances."""
    import time

    from repro.core import get_solver
    from repro.data import (make_sparse_svm_csr, make_sparse_svm_data,
                            make_svm_data)
    from repro.fleet import FleetProblem, FleetSolver

    if args.dataset == "libsvm":
        ap.error("--problems fans out synthetic instances; use --dataset "
                 "dense or sparse (one libsvm file is one problem)")
    sparse_fmt = args.block_format == "sparse"

    probs = []
    for i in range(args.problems):
        seed = args.seed + i
        if args.dataset == "dense":
            X, y = make_svm_data(args.n, args.m, seed=seed)
        elif sparse_fmt:
            X, y = make_sparse_svm_csr(args.n, args.m,
                                       density=args.density, seed=seed)
        else:
            X, y = make_sparse_svm_data(args.n, args.m,
                                        density=args.density, seed=seed)
        probs.append(FleetProblem(tenant_id=f"p{i}", loss_name=args.loss,
                                  X=X, y=y, lam=args.lam, seed=seed))

    try:
        fleet = FleetSolver(solver=args.solver, engine=args.engine,
                            local_backend=args.backend,
                            block_format=args.block_format,
                            staleness=args.staleness,
                            compression=args.compression,
                            topology=args.topology)
    except ValueError as e:
        ap.error(str(e))

    cls = get_solver(args.solver)
    cfg_kw = {"lam": args.lam, "outer_iters": args.iters}
    if args.solver == "admm":
        cfg_kw["rho"] = args.lam
    cfg = cls.config_cls(**cfg_kw)

    tracer = registry = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics:
        from repro.obs import Registry
        registry = Registry()
    from .obs import build_plane
    plane_rules = None
    if args.health:
        from repro.obs import fleet_rules
        plane_rules = fleet_rules()
    plane = build_plane(args, rules=plane_rules, registry=registry,
                        meta={"cli": "optimize", "solver": args.solver,
                              "engine": args.engine,
                              "problems": args.problems})
    registry = plane.registry if plane.active else registry

    print(f"[optimize] {args.solver} engine={fleet.engine} "
          f"backend={args.backend} block_format={args.block_format} "
          f"grid={P}x{Q} problems={args.problems} "
          f"{args.dataset}({args.n}x{args.m}) loss={args.loss} "
          f"lam={args.lam} (fleet fan-out)")
    t0 = time.perf_counter()
    with plane.crash_guard():
        results = fleet.solve_batch(probs, P=P, Q=Q, cfg=cfg, tol=args.tol,
                                    tracer=plane.tracer_or(tracer),
                                    registry=registry)
    total_s = time.perf_counter() - t0
    for p, res in zip(probs, results):
        obj = res.history[-1]["objective"] if res.history else None
        print(f"  {p.tenant_id:>6} seed={p.seed} iters={res.iters} "
              + (f"f={obj:.6f}" if obj is not None else "f=?")
              + (" converged" if res.converged else ""))

    summary = {
        "solver": args.solver, "engine": fleet.engine,
        "local_backend": args.backend,
        "block_format": args.block_format, "P": P, "Q": Q,
        "n": args.n, "m": args.m, "loss": args.loss, "lam": args.lam,
        "problems": args.problems, "total_s": total_s,
        "solves_per_s": args.problems / total_s,
        "results": [{
            "problem": p.tenant_id, "seed": p.seed, "iters": r.iters,
            "converged": r.converged,
            "objective": (r.history[-1]["objective"]
                          if r.history else None),
        } for p, r in zip(probs, results)],
    }
    if registry is not None:
        summary["metrics"] = registry.snapshot()
    if plane.active:
        summary["obs"] = plane.finalize()
    if tracer is not None:
        tracer.write_chrome_trace(args.trace)
        base, _ = os.path.splitext(args.trace)
        tracer.write_jsonl(base + ".jsonl")
        print(f"[optimize] trace: {len(tracer.events)} events -> "
              f"{args.trace} (+ {base + '.jsonl'})")
    print(json.dumps(summary, indent=1))
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(summary, fh, indent=1)
    return summary


if __name__ == "__main__":
    main()
