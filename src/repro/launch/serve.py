"""Serving driver: thin CLI over the continuous-batching engine.

Builds a synthetic mixed-length request trace and drives
``repro.serve.InferenceEngine`` (paged KV cache, prefill/decode
interleave, per-request sampling).  The old static prefill+decode loop
lives on in ``static_batch_generate`` as the benchmark baseline
(benchmarks/serve_bench.py).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --requests 8 --prompt-len 8 --prompt-len-max 32 --gen 16 \
        --temperature 0.8 --top-k 50
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import Transformer, reduced
from ..serve import EngineConfig, InferenceEngine, Request, SamplingParams


def build_trace(cfg, n_requests, plen_min, plen_max, gen_min, gen_max,
                sampling: SamplingParams, seed=0, rid_base=0):
    """Synthetic mixed-length trace: random prompts, per-request seeds."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(plen_min, plen_max + 1))
        gen = int(rng.integers(gen_min, gen_max + 1))
        prompt = rng.integers(0, cfg.vocab, size=plen)
        sp = SamplingParams(temperature=sampling.temperature,
                            top_k=sampling.top_k, top_p=sampling.top_p,
                            seed=sampling.seed + i)
        reqs.append(Request(rid=rid_base + i, prompt=prompt,
                            max_new_tokens=gen, sampling=sp))
    return reqs


def static_batch_generate(model, params, requests, batch_size):
    """The seed-era static loop: fixed batches, right-padded prefill, every
    slot decodes until the slowest request in its batch finishes.

    Returns {rid: generated tokens} -- the baseline continuous batching
    is measured against (benchmarks/serve_bench.py).  The jitted
    prefill/decode are cached on ``model`` so repeated calls (benchmark
    warmup vs timed pass) hit the same compilation cache.

    Kept verbatim as the seed behaved, flaw included: in a batch of
    MIXED prompt lengths the shorter rows are right-padded and their
    first token argmaxed at the padded position, with the padding's k/v
    visible to decode attention -- the outputs for those rows are not
    the model's answer to the unpadded prompt.  Token-for-token
    equivalence with the engine therefore only holds for uniform-length
    batches (tests/test_serve.py groups its chunks that way); the
    mixed-length benchmark compares throughput of the seed's actual
    behavior, not its correctness."""
    outputs = {}
    jits = getattr(model, "_static_serve_jits", None)
    if jits is None:
        jits = (jax.jit(lambda p, b, cl: model.prefill(p, b, cl),
                        static_argnums=2),
                jax.jit(model.decode_step))
        model._static_serve_jits = jits
    prefill, decode = jits
    for lo in range(0, len(requests), batch_size):
        batch = requests[lo: lo + batch_size]
        B = len(batch)
        S = max(len(r.prompt) for r in batch)
        gen = max(r.max_new_tokens for r in batch)
        toks = np.zeros((B, S), np.int32)
        for b, r in enumerate(batch):
            toks[b, : len(r.prompt)] = r.prompt
        logits, cache = prefill(params, {"tokens": jnp.asarray(toks)},
                                S + gen)
        rows = []
        for _ in range(gen):
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            rows.append(np.asarray(nxt))
            logits, cache = decode(params, cache, {"tokens": nxt[:, None]})
        out = np.stack(rows, axis=1)
        for b, r in enumerate(batch):
            outputs[r.rid] = out[b, : r.max_new_tokens]
    return outputs


def legacy_generate(cfg, model, params, args):
    """Seed-era toy loop for archs the paged engine can't serve yet
    (recurrent mixers, xattn encoders, embedding frontends): one fixed
    batch of random inputs, contiguous ring-buffer cache, greedy decode.
    Returns {index: generated tokens} like the engine path."""
    key = jax.random.PRNGKey(1)
    B, S = args.requests, args.prompt_len
    cache_len = S + args.gen
    batch = {}
    if cfg.embed_input == "tokens":
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    else:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            cfg.cdtype)
    if cfg.encoder_len:
        batch["encoder"] = jax.random.normal(
            key, (B, cfg.encoder_len, cfg.d_model))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
    decode = jax.jit(model.decode_step)
    logits, cache = prefill(params, batch)
    toks = []
    for i in range(args.gen):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks.append(np.asarray(nxt))
        step_in = {"tokens": nxt[:, None]}
        if cfg.embed_input != "tokens":
            step_in = {"embeds": jax.random.normal(
                jax.random.fold_in(key, i), (B, 1, cfg.d_model), cfg.cdtype)}
        if cfg.encoder_len:
            step_in["encoder"] = batch["encoder"]
        logits, cache = decode(params, cache, step_in)
    out = np.stack(toks, axis=1)
    return {i: out[i] for i in range(B)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="minimum prompt length of the trace")
    ap.add_argument("--prompt-len-max", type=int, default=None,
                    help="maximum prompt length (default: --prompt-len)")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--gen-min", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=256)
    ap.add_argument("--max-seq-len", type=int, default=512)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="trace the serve loop and write Chrome-trace "
                         "JSON here (engine_step > admission / prefill / "
                         "decode_step spans, preempt/finish/reject "
                         "instants); open in chrome://tracing or "
                         "ui.perfetto.dev")
    ap.add_argument("--metrics", action="store_true",
                    help="print the metrics-registry snapshot (the same "
                         "schema solver telemetry uses) after the run")
    from .obs import add_obs_flags
    add_obs_flags(ap)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Transformer(cfg)
    params = jax.jit(lambda k: model.init(k)[0])(jax.random.PRNGKey(0))

    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              seed=args.seed)
    plen_max = args.prompt_len_max or args.prompt_len
    gen_min = args.gen_min or args.gen
    if plen_max < args.prompt_len:
        ap.error("--prompt-len-max must be >= --prompt-len")
    if gen_min > args.gen:
        ap.error("--gen-min must be <= --gen")
    if args.prompt_len + gen_min > args.max_seq_len:
        ap.error(f"--prompt-len + --gen-min exceeds --max-seq-len "
                 f"({args.max_seq_len}): every request would be rejected")
    reqs = build_trace(cfg, args.requests, args.prompt_len, plen_max,
                       gen_min, args.gen, sampling, seed=args.seed)

    tracer = registry = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.metrics:
        from repro.obs import Registry
        registry = Registry()
    from .obs import build_plane
    plane_rules = None
    if args.health:
        from repro.obs import serve_rules
        plane_rules = serve_rules()
    plane = build_plane(args, rules=plane_rules, registry=registry,
                        meta={"cli": "serve", "arch": args.arch})
    registry = plane.registry if plane.active else registry
    try:
        engine = InferenceEngine(model, params, EngineConfig(
            max_slots=args.slots, page_size=args.page_size,
            num_pages=args.num_pages, max_seq_len=args.max_seq_len),
            tracer=plane.tracer_or(tracer), registry=registry,
            monitor=plane.monitor)
    except NotImplementedError as e:
        print(f"note: {e}")
        print("falling back to the seed static loop (greedy, fixed batch)")
        outputs = legacy_generate(cfg, model, params, args)
        print("generated token ids (first request):",
              outputs[min(outputs)][:16])
        return outputs
    with plane.crash_guard():
        outputs = engine.run(reqs)

    s = engine.metrics.summary()
    print(f"{len(outputs)} requests, {s['generated_tokens']} tokens in "
          f"{s['elapsed_s']:.2f}s ({s['tokens_per_sec']:.1f} tok/s); "
          f"ttft p50 {s['ttft_s']['p50'] * 1e3:.0f} ms, "
          f"latency p99 {s['latency_s']['p99'] * 1e3:.0f} ms")
    print(json.dumps(s, indent=1))
    if registry is not None:
        print(json.dumps(registry.snapshot(), indent=1))
    if plane.active:
        print(json.dumps({"obs": plane.finalize()}, indent=1))
    if tracer is not None:
        tracer.write_chrome_trace(args.trace)
        print(f"trace: {len(tracer.events)} events -> {args.trace}")
    if s["rejections"]:
        print(f"{s['rejections']} request(s) rejected "
              f"(prompt + gen > --max-seq-len, or queue full)")
    if outputs:
        print("generated token ids (first request):",
              outputs[min(outputs)][:16])
    return outputs


if __name__ == "__main__":
    main()
