"""Batched serving driver: prefill + decode loop with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import Transformer, reduced
from .mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_mesh((jax.device_count(), 1), ("data", "model"))
    model = Transformer(cfg, mesh=mesh)

    with jax.set_mesh(mesh):
        params = jax.jit(lambda k: model.init(k)[0])(jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(1)
        B, S = args.batch, args.prompt_len
        cache_len = S + args.gen
        batch = {}
        if cfg.embed_input == "tokens":
            batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
        else:
            batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                                cfg.cdtype)
        if cfg.encoder_len:
            batch["encoder"] = jax.random.normal(
                key, (B, cfg.encoder_len, cfg.d_model))

        prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
        decode = jax.jit(model.decode_step, donate_argnums=(1,))

        t0 = time.perf_counter()
        logits, cache = prefill(params, batch)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        toks = []
        t0 = time.perf_counter()
        for i in range(args.gen):
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            toks.append(np.asarray(nxt))
            step_in = {"tokens": nxt[:, None]}
            if cfg.embed_input != "tokens":
                step_in = {"embeds": jax.random.normal(
                    jax.random.fold_in(key, i), (B, 1, cfg.d_model),
                    cfg.cdtype)}
            if cfg.encoder_len:
                step_in["encoder"] = batch["encoder"]
            logits, cache = decode(params, cache, step_in)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0

    out = np.stack(toks, axis=1)
    print(f"prefill {S} toks x {B} seqs: {t_prefill*1e3:.1f} ms; "
          f"decode {args.gen} steps: {t_decode*1e3:.1f} ms "
          f"({t_decode/args.gen*1e3:.1f} ms/tok)")
    print("generated token ids (first seq):", out[0][:16])
    return out


if __name__ == "__main__":
    main()
