"""repro.online -- streaming observations into warm-started doubly
distributed solves behind the live scorer.

The paper's solvers are batch algorithms: P x Q grid, fixed (X, y),
outer iterations to convergence.  This package turns them into a
service.  New observations arrive as requests, pass an admission queue
(bounded; shed on overload), land in a fixed-capacity ring buffer
sharded into the same P x Q grid, and trigger *incremental* updates:
warm-started, row-gated solver passes (``Solver.update``) that only
move the dual of the touched cells while the primal stays exact for
the whole window.  Meanwhile ``LinearScorer`` keeps serving the last
published model from a versioned snapshot swapped in atomically (and,
optionally, persisted through ``repro.checkpoint`` for crash
recovery).

Modules:
  * ``queue``    -- :class:`AdmissionQueue`: bounded ingest,
                    reject-on-full, FIFO drain-coalescing
  * ``store``    -- :class:`GridStore`: constant-shape observation ring
                    sharded into P row slabs; reports touched rows
  * ``snapshot`` -- :class:`ModelSnapshot` / :class:`SnapshotBook`:
                    atomic publish/read hand-off + checkpoint-backed
                    durability and recovery
  * ``service``  -- :class:`OnlineSolverService`: the whole loop, with
                    tracer spans and staleness/throughput metrics

See docs/architecture.md for where this sits in the stack and
docs/consistency.md for the snapshot-staleness guarantees.
"""
from .queue import AdmissionQueue, QueueFullError
from .service import OnlineConfig, OnlineSolverService
from .snapshot import ModelSnapshot, SnapshotBook
from .store import GridStore

__all__ = [
    "AdmissionQueue", "QueueFullError",
    "OnlineConfig", "OnlineSolverService",
    "ModelSnapshot", "SnapshotBook",
    "GridStore",
]
