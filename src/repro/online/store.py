"""Fixed-capacity observation store sharded into the P x Q grid.

The streaming solver needs constant array shapes -- a growing n would
recompile the solver program on every batch.  ``GridStore`` therefore
pre-allocates a ``capacity``-row buffer (rounded up so P divides it),
fills it sequentially, and wraps around ring-buffer style once full
(oldest observations are overwritten; the effective training window is
the last ``capacity`` rows of the stream).

Because the solver partitions rows into P contiguous slabs of
``n_p = capacity / P`` rows, a batch written at the ring cursor lands
in one or two adjacent row partitions -- exactly the "touched cells"
set the incremental gated D3CA pass is restricted to.  ``insert``
returns the touched row indices so the service can build the gate.

Rows never written stay all-zero with ``filled_mask == 0``; the
service always gates them off (their dual is frozen at zero and a
zero-feature row contributes nothing to w), so passing the full buffer
to the solver is safe.  The one caveat is normalization: the solver's
1/n objective scaling counts ``capacity`` rows, so until the buffer
fills, the effective regularization is ``lam * capacity / filled``
relative to the filled-rows problem.  Deliberate: shapes stay
constant, and the bias decays to zero as the buffer fills.
"""
from __future__ import annotations

import threading

import numpy as np


def _ceil_to(x: int, k: int) -> int:
    return (x + k - 1) // k * k


class GridStore:
    """Ring buffer of the last ``capacity`` stream observations.

    Args:
      m: feature dimension.
      capacity: observation window size (rounded up to a multiple of P).
      P, Q: the solver grid this buffer will be partitioned into.
    """

    def __init__(self, m: int, capacity: int, P: int, Q: int):
        self.m = int(m)
        self.P = int(P)
        self.Q = int(Q)
        self.capacity = _ceil_to(int(capacity), self.P)
        self.n_p = self.capacity // self.P
        self.X = np.zeros((self.capacity, self.m), np.float32)
        self.y = np.zeros((self.capacity,), np.float32)
        self.filled_mask = np.zeros((self.capacity,), np.float32)
        self._cursor = 0          # next slot to write (ring)
        self._written = 0         # total rows ever written
        self._lock = threading.Lock()

    def insert(self, Xb, yb) -> np.ndarray:
        """Write a batch at the ring cursor.

        Args:
          Xb: (b, m) rows; b may exceed capacity (only the last
            ``capacity`` rows survive, matching ring semantics).
          yb: (b,) labels.

        Returns:
          The touched row indices (np.int64, sorted, unique) -- the
          gate set for the next incremental pass.

        Raises:
          ValueError: on a feature-dimension mismatch.
        """
        Xb = np.asarray(Xb, np.float32)
        yb = np.asarray(yb, np.float32)
        if Xb.ndim != 2 or Xb.shape[1] != self.m:
            raise ValueError(f"expected (b, {self.m}); got {Xb.shape}")
        b = Xb.shape[0]
        if b > self.capacity:       # only the tail survives a giant batch
            Xb, yb, b = Xb[-self.capacity:], yb[-self.capacity:], \
                self.capacity
        with self._lock:
            idx = (self._cursor + np.arange(b)) % self.capacity
            self.X[idx] = Xb
            self.y[idx] = yb
            self.filled_mask[idx] = 1.0
            self._cursor = int((self._cursor + b) % self.capacity)
            self._written += b
        return np.unique(idx)

    def touched_partitions(self, rows: np.ndarray) -> np.ndarray:
        """Row partitions (p indices) a set of row indices lands in."""
        return np.unique(np.asarray(rows) // self.n_p)

    @property
    def filled(self) -> int:
        """Rows holding a real observation (<= capacity)."""
        with self._lock:
            return int(self.filled_mask.sum())

    @property
    def written(self) -> int:
        """Total rows ever written (>= filled once the ring wraps)."""
        with self._lock:
            return self._written
