"""Admission control for observation-bearing requests.

The online service sits in front of a solver whose update passes take
milliseconds to seconds; traffic does not.  ``AdmissionQueue`` is the
bounded buffer between the two: producers ``submit`` observation
batches and are *rejected* (not blocked) when the queue is full --
load-shedding at admission keeps the update path's latency bounded
instead of letting a backlog grow without bound.  ``drain`` pops
pending requests and coalesces them into one training batch, so one
warm-started solver pass absorbs a burst.

Thread-safe; pure stdlib (the queue never touches jax).
"""
from __future__ import annotations

import threading
from typing import List, Optional, Tuple

import numpy as np


class QueueFullError(RuntimeError):
    """Raised by :meth:`AdmissionQueue.submit` when admission would
    exceed ``capacity`` pending observations (the request is shed)."""


class AdmissionQueue:
    """Bounded FIFO of observation batches awaiting an update pass.

    Args:
      capacity: maximum number of pending *observations* (rows summed
        over queued batches); 0 or negative means unbounded.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._batches: List[Tuple[np.ndarray, np.ndarray, int]] = []
        self._pending_rows = 0
        self._seq = 0           # observations ever admitted
        self.admitted = 0
        self.rejected = 0

    def submit(self, X, y) -> int:
        """Admit a batch of observations.

        Args:
          X: (b, m) feature rows.
          y: (b,) labels.

        Returns:
          The stream sequence number of the LAST admitted observation
          (1-based; monotone over the life of the queue).

        Raises:
          QueueFullError: when admitting would exceed ``capacity``
            pending rows; the batch is dropped whole (no partial
            admission).
          ValueError: on mismatched X/y lengths.
        """
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        if X.ndim != 2 or y.shape != (X.shape[0],):
            raise ValueError(f"expected (b, m) X and (b,) y; got "
                             f"{X.shape} / {y.shape}")
        b = X.shape[0]
        with self._lock:
            if 0 < self.capacity < self._pending_rows + b:
                self.rejected += b
                raise QueueFullError(
                    f"admission queue full ({self._pending_rows} pending "
                    f"rows + {b} > capacity {self.capacity})")
            self._seq += b
            self.admitted += b
            self._pending_rows += b
            self._batches.append((X, y, self._seq))
            return self._seq

    def drain(self, max_rows: Optional[int] = None):
        """Pop pending batches (FIFO) and coalesce them.

        Args:
          max_rows: stop after at least this many rows have been popped
            (whole batches only; None drains everything).

        Returns:
          ``(X, y, seq)`` -- the concatenated rows and the sequence
          number of the last row included -- or ``None`` when nothing
          is pending.
        """
        with self._lock:
            if not self._batches:
                return None
            take, rows = [], 0
            while self._batches and (max_rows is None or rows < max_rows):
                b = self._batches.pop(0)
                take.append(b)
                rows += len(b[1])
            self._pending_rows -= rows
        X = np.concatenate([b[0] for b in take], axis=0)
        y = np.concatenate([b[1] for b in take], axis=0)
        return X, y, take[-1][2]

    @property
    def pending_rows(self) -> int:
        with self._lock:
            return self._pending_rows

    @property
    def seq(self) -> int:
        """Observations ever admitted (the ingest high-water mark)."""
        with self._lock:
            return self._seq
