"""The online learning service: stream observations into warm-started
doubly distributed solves behind the live scorer.

Request lifecycle (each arrow is a tracer span and a metrics site):

    submit() ──▶ AdmissionQueue ──▶ run_pending():
                   (shed on full)     online/ingest   GridStore.insert
                                      online/update   Solver.update
                                                      (gated, warm-started)
                                      online/swap     SnapshotBook.publish
                                                      LinearScorer.update_weights
    score() ──▶ LinearScorer (current snapshot; staleness accounted)

The solver side reuses the repo's whole stack: ``Solver.update`` runs
``passes`` warm-started outer iterations of the configured solver
(gated D3CA by default) in which only the rows the new batch landed on
may move their dual, through whichever engine x backend x block-format
cell the service was configured with.  Scoring never blocks on
training: the scorer reads the last *published* weights, swapped in by
one atomic reference assignment, and the gap between "what the scorer
serves" and "what the stream has seen" is exported as the staleness
gauge and version lag.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..core.solver import get_solver
from ..obs import NULL_TRACER, Registry, as_tracer
from ..serve.scoring import LinearScorer
from .queue import AdmissionQueue
from .snapshot import SnapshotBook
from .store import GridStore


@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    """Static configuration of an :class:`OnlineSolverService`.

    Attributes:
      m: feature dimension of the stream.
      capacity: observation window (GridStore rows; rounded up so P
        divides it).
      P, Q: solver grid.
      loss: loss name (see ``repro.core.losses``).
      solver: registry name; must support row gating (``d3ca``).
      engine / local_backend / block_format / staleness / compression /
        topology: the usual solver knobs, threaded verbatim.
      solver_cfg: optional solver config (its ``outer_iters`` is
        overridden by ``passes`` for each update).
      passes: warm-started outer iterations per drained batch.
      queue_capacity: admission bound in pending observation rows.
      max_update_rows: cap on rows drained into one update pass.
    """
    m: int
    capacity: int = 512
    P: int = 2
    Q: int = 2
    loss: str = "hinge"
    solver: str = "d3ca"
    engine: str = "simulated"
    local_backend: str = "ref"
    block_format: str = "dense"
    staleness: int = 0
    compression: Optional[str] = None
    topology: Optional[str] = None
    solver_cfg: Optional[object] = None
    passes: int = 1
    queue_capacity: int = 4096
    max_update_rows: Optional[int] = None


class OnlineSolverService:
    """Ties admission, the observation store, the incremental solver,
    snapshot publication, and the live scorer into one object.

    Args:
      config: an :class:`OnlineConfig`.
      mesh: jax mesh for non-simulated engines (and grid-sharded
        scoring); None runs the simulated engine and a single-device
        scorer.
      manager: optional :class:`~repro.checkpoint.manager.
        CheckpointManager` -- when given, every published version is
        persisted and :meth:`recover` can resume after a crash.
      tracer: a :class:`repro.obs.Tracer` (spans ``online/ingest``,
        ``online/update``, ``online/swap``, ``online/score``).
      registry: a :class:`repro.obs.Registry`.  The service exports
        counters ``online/ingested`` / ``online/updates`` /
        ``online/scored`` / ``online/rejected``, gauges
        ``online/staleness_s`` (age of the served snapshot) and
        ``online/version_lag`` (admitted observations the served model
        has not seen) and ``online/w_norm`` (L2 norm of the published
        weights -- the divergence health rule's NaN sentinel, since
        incremental updates skip the per-iter objective evaluation),
        and histograms ``online/update_s`` / ``online/swap_s``.
      monitor: a :class:`repro.obs.HealthMonitor`; its rate-limited
        ``poll()`` runs after every publish and on every ingest, so a
        NaN model, a staleness breach, or queue saturation is noticed
        (and its postmortem dump fired) while the service is live.
      clock: injectable wall-clock for staleness math (tests freeze it).
    """

    def __init__(self, config: OnlineConfig, *, mesh=None, manager=None,
                 tracer=None, registry: Optional[Registry] = None,
                 monitor=None, clock=time.monotonic):
        solver_cls = get_solver(config.solver)
        if not solver_cls.supports_row_gate:
            raise ValueError(
                f"solver {config.solver!r} has no incremental row-gate "
                "path; the online service needs one (use 'd3ca')")
        self.config = config
        self.mesh = mesh
        self.tracer = as_tracer(tracer) if tracer is not None else NULL_TRACER
        self.registry = registry if registry is not None else Registry()
        self.clock = clock
        self.solver = solver_cls(
            engine=config.engine, local_backend=config.local_backend,
            block_format=config.block_format, staleness=config.staleness,
            compression=config.compression, topology=config.topology)
        self.queue = AdmissionQueue(capacity=config.queue_capacity)
        self.store = GridStore(config.m, config.capacity,
                               config.P, config.Q)
        cap = self.store.capacity
        self.book = SnapshotBook(np.zeros((config.m,), np.float32),
                                 np.zeros((cap,), np.float32),
                                 manager=manager, clock=clock)
        self.scorer = LinearScorer(np.zeros((config.m,), np.float32),
                                   mesh, loss=config.loss)
        self._labels = {"solver": config.solver, "engine": config.engine}
        self.monitor = monitor
        self.last_result = None

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def submit(self, X, y) -> int:
        """Admit an observation batch (may raise
        :class:`~repro.online.queue.QueueFullError` -- callers retry or
        shed; the counters record either way)."""
        with self.tracer.span("online/ingest", rows=int(np.shape(X)[0])):
            try:
                seq = self.queue.submit(X, y)
            except Exception:
                self.registry.counter("online/rejected", **self._labels)\
                    .inc(int(np.shape(X)[0]))
                if self.monitor is not None:
                    self.monitor.poll()
                raise
        self.registry.counter("online/ingested", **self._labels)\
            .inc(int(np.shape(X)[0]))
        self._gauge_staleness()
        if self.monitor is not None:
            self.monitor.poll()
        return seq

    # ------------------------------------------------------------------
    # update
    # ------------------------------------------------------------------
    def run_pending(self) -> Optional[int]:
        """Drain the queue and fold the batch into the model.

        One call = at most one warm-started gated solver pass over the
        touched cells, then one atomic snapshot publish + scorer swap.

        Returns:
          The new snapshot version, or None when nothing was pending.
        """
        batch = self.queue.drain(self.config.max_update_rows)
        if batch is None:
            return None
        Xb, yb, seq = batch
        cur = self.book.current()
        with self.tracer.span("online/update", rows=len(yb)):
            t0 = self.clock()
            touched = self.store.insert(Xb, yb)
            warm = (cur.w, cur.alpha)
            res = self.solver.update(
                self.config.loss, self.store.X, self.store.y,
                touched=touched, warm_start=warm,
                P=self.config.P, Q=self.config.Q,
                cfg=self.config.solver_cfg, mesh=self.mesh,
                passes=self.config.passes,
                tracer=(self.tracer if self.tracer is not NULL_TRACER
                        else None),
                registry=self.registry, record_history=False)
            self.registry.histogram("online/update_s", **self._labels)\
                .observe(self.clock() - t0)
        with self.tracer.span("online/swap"):
            t0 = self.clock()
            snap = self.book.publish(np.asarray(res.w),
                                     np.asarray(res.alpha), seq)
            self.scorer.update_weights(snap.w, version=snap.version)
            self.registry.histogram("online/swap_s", **self._labels)\
                .observe(self.clock() - t0)
        self.registry.counter("online/updates", **self._labels).inc()
        # L2 norm of the published weights: NaN/inf anywhere in w makes
        # the norm non-finite, which is what the divergence health rule
        # watches (incremental updates run with record_history=False, so
        # no solver/objective gauge is written on this path)
        self.registry.gauge("online/w_norm", **self._labels)\
            .set(float(np.linalg.norm(np.asarray(snap.w))))
        self.last_result = res
        self._gauge_staleness()
        if self.monitor is not None:
            self.monitor.poll()
        return snap.version

    def drain_all(self) -> int:
        """Run update passes until the queue is empty; returns the
        number of passes run."""
        n = 0
        while self.run_pending() is not None:
            n += 1
        return n

    # ------------------------------------------------------------------
    # serve
    # ------------------------------------------------------------------
    def score(self, X) -> np.ndarray:
        """Margins under the currently served snapshot (never blocks on
        a concurrent update pass)."""
        with self.tracer.span("online/score", rows=int(np.shape(X)[0])):
            out = self.scorer.score(X)
        self.registry.counter("online/scored", **self._labels)\
            .inc(int(np.shape(X)[0]))
        self._gauge_staleness()
        if self.monitor is not None:
            self.monitor.poll()     # staleness grows while only scoring
        return out

    def predict(self, X) -> np.ndarray:
        """Labels / probabilities under the served snapshot."""
        out = self.scorer.predict(X)
        self.registry.counter("online/scored", **self._labels)\
            .inc(int(np.shape(X)[0]))
        self._gauge_staleness()
        return out

    # ------------------------------------------------------------------
    # bookkeeping
    # ------------------------------------------------------------------
    def _gauge_staleness(self):
        cur = self.book.current()
        self.registry.gauge("online/staleness_s", **self._labels)\
            .set(self.clock() - cur.trained_at)
        self.registry.gauge("online/version_lag", **self._labels)\
            .set(self.queue.seq - cur.trained_seq)

    @property
    def staleness_s(self) -> float:
        """Age of the snapshot the scorer is serving."""
        return self.clock() - self.book.current().trained_at

    @property
    def version_lag(self) -> int:
        """Admitted observations the served model has not absorbed."""
        return self.queue.seq - self.book.current().trained_seq

    def recover(self) -> Optional[int]:
        """Restore the newest persisted snapshot (see
        :meth:`SnapshotBook.recover`) and point the scorer at it.

        Returns the recovered version, or None without a manager /
        checkpoints."""
        cap = self.store.capacity
        snap = self.book.recover(np.zeros((self.config.m,), np.float32),
                                 np.zeros((cap,), np.float32))
        if snap is None:
            return None
        self.scorer.update_weights(snap.w, version=snap.version)
        return snap.version

    def stats(self) -> dict:
        """One-call service summary (counters + staleness + store)."""
        cur = self.book.current()
        out = {
            "version": cur.version,
            "trained_seq": cur.trained_seq,
            "ingested": self.queue.admitted,
            "rejected": self.queue.rejected,
            "pending_rows": self.queue.pending_rows,
            "version_lag": self.version_lag,
            "staleness_s": self.staleness_s,
            "store_filled": self.store.filled,
            "store_capacity": self.store.capacity,
            "rows_scored": self.scorer.rows_scored,
            "score_rows_per_sec": self.scorer.rows_per_sec,
        }
        if self.monitor is not None:
            out["health"] = self.monitor.status
        return out
