"""Versioned model snapshots with an atomic publish/read hand-off.

``SnapshotBook`` is the synchronization point between the update path
(one writer) and the scoring path (many readers): ``publish`` builds an
immutable :class:`ModelSnapshot` off to the side and swaps the current
reference under a lock, so ``current()`` always returns a *complete*
(version, w, alpha, trained_seq, trained_at) tuple -- readers see the
old snapshot or the new one, never a mix.  Durability reuses
``repro.checkpoint.manager``: each published version is written as
checkpoint ``step_<version>`` via the manager's write-to-tmp +
atomic-rename protocol, so a crash mid-publish can never corrupt the
latest on-disk snapshot (tests/test_checkpoint.py pins this), and
``recover`` restores the newest complete version after a restart.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from ..checkpoint.manager import CheckpointManager


@dataclasses.dataclass(frozen=True)
class ModelSnapshot:
    """One immutable published model version.

    Attributes:
      version: monotone snapshot version (0 is the initial zero model).
      w: (m,) weights.
      alpha: (capacity,) dual iterate carried for the next warm start
        (None for primal-only solvers).
      trained_seq: stream sequence number the model has absorbed --
        ``ingested_seq - trained_seq`` is the version lag.
      trained_at: publish wall-clock (the staleness zero point).
    """
    version: int
    w: np.ndarray
    alpha: Optional[np.ndarray]
    trained_seq: int
    trained_at: float


class SnapshotBook:
    """Single-writer / many-reader registry of model snapshots.

    Args:
      w0: (m,) initial weights (version 0).
      alpha0: optional initial dual.
      manager: optional :class:`CheckpointManager`; when given, every
        publish persists the snapshot as checkpoint step ``version``
        (synchronously by default -- see ``async_persist``).
      async_persist: hand the disk write to the manager's background
        thread so ``publish`` only blocks for the reference swap.
      clock: injectable time source (tests freeze it).
    """

    def __init__(self, w0, alpha0=None, *,
                 manager: Optional[CheckpointManager] = None,
                 async_persist: bool = True, clock=time.monotonic):
        self._lock = threading.Lock()
        self._manager = manager
        self._async = async_persist
        self._clock = clock
        self._current = ModelSnapshot(
            version=0, w=np.asarray(w0, np.float32),
            alpha=None if alpha0 is None else np.asarray(alpha0, np.float32),
            trained_seq=0, trained_at=clock())

    def current(self) -> ModelSnapshot:
        """The latest published snapshot (always complete)."""
        with self._lock:
            return self._current

    def publish(self, w, alpha, trained_seq: int) -> ModelSnapshot:
        """Publish a new version; returns the new snapshot.

        The snapshot (and, when persistence is on, its on-disk
        checkpoint handoff) is prepared BEFORE the reference swap, so
        the swap itself is one assignment under the lock.
        """
        with self._lock:
            version = self._current.version + 1
        snap = ModelSnapshot(
            version=version, w=np.asarray(w, np.float32),
            alpha=None if alpha is None else np.asarray(alpha, np.float32),
            trained_seq=int(trained_seq), trained_at=self._clock())
        if self._manager is not None:
            tree = {"w": snap.w,
                    "trained_seq": np.asarray(snap.trained_seq, np.int64)}
            if snap.alpha is not None:
                tree["alpha"] = snap.alpha
            if self._async:
                self._manager.save_async(version, tree)
            else:
                self._manager.save(version, tree)
        with self._lock:
            self._current = snap
        return snap

    def flush(self):
        """Block until any background persist completed (surfacing its
        error, if one failed)."""
        if self._manager is not None:
            self._manager.wait()

    def recover(self, like_w, like_alpha=None) -> Optional[ModelSnapshot]:
        """Restore the newest complete on-disk version (crash recovery).

        Incomplete writes (leftover ``.tmp`` directories from a crash
        mid-publish) are invisible to the manager's ``latest_step``, so
        recovery lands on the newest snapshot that finished its atomic
        rename.

        Args:
          like_w: (m,) template array fixing the weight shape/dtype.
          like_alpha: optional dual template (omit for primal-only).

        Returns:
          The recovered snapshot (now current), or None when no
          complete checkpoint exists (the book keeps version 0).
        """
        if self._manager is None or self._manager.latest_step() is None:
            return None
        like = {"w": np.asarray(like_w, np.float32),
                "trained_seq": np.asarray(0, np.int64)}
        if like_alpha is not None:
            like["alpha"] = np.asarray(like_alpha, np.float32)
        step, tree = self._manager.restore(like)
        snap = ModelSnapshot(
            version=int(step), w=np.asarray(tree["w"]),
            alpha=(np.asarray(tree["alpha"]) if "alpha" in tree else None),
            trained_seq=int(tree["trained_seq"]),
            trained_at=self._clock())
        with self._lock:
            self._current = snap
        return snap
